"""`repro.stream`: sources, router, scheduler, snapshot + streaming verbs.

Covers the PR-3 acceptance gates:
  * replayable sources: deterministic in seed, ordered, shape-sensitive,
    JSONL file replay round-trips;
  * consistent-hash router: stable placement, bounded remapping on
    membership change, drop-oldest vs block backpressure;
  * `ingest`/`stats` verbs: monotonic ack cursor, bounded queue rejects
    whole batches as `overloaded`, drain-update applies the backlog,
    queue depth is observable;
  * session eviction under max_sessions=1 with concurrent ingest: the
    evicted client resyncs without losing acked reviews;
  * scheduler: micro-batching, staleness-forced applies, drift-policy
    refits vs always/never;
  * snapshot/restore: codec-exact round trip, clients recover via resync.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import VedaliaClient, VedaliaServer, protocol
from repro.core import views as views_lib
from repro.core.views import TopicView
from repro.data import reviews as reviews_data
from repro.stream import (
    IncrementalScheduler,
    ReviewEvent,
    StreamRouter,
    StreamSpec,
    load_events,
    pump,
    replay,
    restore_server,
    save_events,
    snapshot_server,
    synthetic_events,
)
from repro.stream.sources import rate_at

QUICK = StreamSpec(num_products=3, duration=30.0, rate=2.0, shape="burst",
                   shift_at=15.0, seed=0)


def _reviews(n=20, vocab=120, seed=0):
    return reviews_data.generate(reviews_data.SyntheticSpec(
        num_reviews=n, vocab_size=vocab, num_topics=4, mean_tokens=25,
        seed=seed)).reviews


def _client(**kw):
    return VedaliaClient(backend="jnp", num_sweeps=4, update_sweeps=1, **kw)


# -- sources -----------------------------------------------------------------


def test_synthetic_events_deterministic_and_ordered():
    a = synthetic_events(QUICK)
    b = synthetic_events(QUICK)
    assert len(a) == len(b) > 20
    assert [(e.t, e.product_id) for e in a] == [(e.t, e.product_id)
                                                for e in b]
    ts = [e.t for e in a]
    assert ts == sorted(ts) and ts[-1] < QUICK.duration
    assert [e.seq for e in a] == list(range(len(a)))
    np.testing.assert_array_equal(
        a[0].review.tokens, b[0].review.tokens)


def test_traffic_shapes_have_distinct_rates():
    burst = dataclasses.replace(QUICK, shape="burst")
    assert rate_at(burst, 1.0) == burst.rate * burst.burst_factor
    assert rate_at(burst, burst.burst_len + 1.0) \
        == burst.rate * burst.idle_factor
    diurnal = dataclasses.replace(QUICK, shape="diurnal")
    peak = rate_at(diurnal, diurnal.diurnal_period / 4)
    trough = rate_at(diurnal, 3 * diurnal.diurnal_period / 4)
    assert peak > diurnal.rate > trough >= 0
    with pytest.raises(ValueError, match="unknown stream shape"):
        rate_at(dataclasses.replace(QUICK, shape="tsunami"), 0.0)


def test_concept_shift_rotates_vocabulary():
    plain = synthetic_events(dataclasses.replace(QUICK, shift_at=None))
    shifted = synthetic_events(QUICK)  # shift_at=15.0
    pre = next(e for e in shifted if e.t < QUICK.shift_at)
    post = next(e for e in shifted if e.t >= QUICK.shift_at)
    twin = next(e for e in plain if e.seq == post.seq)
    np.testing.assert_array_equal(  # pre-shift events are untouched
        pre.review.tokens,
        next(e for e in plain if e.seq == pre.seq).review.tokens)
    np.testing.assert_array_equal(
        post.review.tokens,
        (np.asarray(twin.review.tokens, np.int64) + QUICK.vocab_size // 2)
        % QUICK.vocab_size)


def test_file_replay_roundtrip(tmp_path):
    events = synthetic_events(QUICK)[:10]
    path = str(tmp_path / "stream.jsonl")
    assert save_events(events, path) == 10
    loaded = load_events(path)
    assert len(loaded) == 10
    for a, b in zip(events, loaded):
        assert (a.seq, a.t, a.product_id) == (b.seq, b.t, b.product_id)
        np.testing.assert_array_equal(a.review.tokens, b.review.tokens)
        assert a.review.rating == b.review.rating
    assert [e.seq for e in replay(path, limit=3)] == [0, 1, 2]


# -- router ------------------------------------------------------------------


def _event(seq, pid, t=0.0):
    return ReviewEvent(seq=seq, t=t, product_id=pid,
                       review=_reviews(n=1, seed=seq)[0])


def test_routing_is_stable_and_remaps_boundedly():
    r1 = StreamRouter([0, 1, 2, 3])
    r2 = StreamRouter([0, 1, 2, 3])
    placement = {pid: r1.route(pid) for pid in range(200)}
    assert placement == {pid: r2.route(pid) for pid in range(200)}
    assert len(set(placement.values())) == 4  # every shard owns something
    # Adding a 5th shard moves well under half the keys (mod-5 would move
    # ~80% of them); that is the point of consistent hashing.
    r1.add_shard(4)
    moved = sum(1 for pid in range(200) if r1.route(pid) != placement[pid])
    assert 0 < moved < 100
    # And every moved key landed on the new shard, not shuffled elsewhere.
    assert all(r1.route(pid) == 4 for pid in range(200)
               if r1.route(pid) != placement[pid])


def test_remove_shard_returns_orphans_and_reroutes():
    router = StreamRouter([0, 1], capacity=8)
    events = [_event(i, pid=i) for i in range(8)]
    for e in events:
        router.offer(e)
    victim = router.route(events[0].product_id)
    orphans = router.remove_shard(victim)
    assert all(router.route(e.product_id) != victim for e in events)
    survivors = router.shard_ids
    assert survivors == [1 - victim]
    for e in orphans:  # re-offer lands on the survivor
        assert router.offer(e)


def test_drop_oldest_policy_bounds_queue():
    router = StreamRouter([0], capacity=3, policy="drop_oldest")
    for i in range(5):
        assert router.offer(_event(i, pid=0))
    assert router.depth(0) == 3
    st = router.stats()
    assert st.dropped == 2 and st.refused == 0 and st.routed == 5
    assert [e.seq for e in router.drain(0)] == [2, 3, 4]  # oldest went first


def test_block_policy_refuses_and_recovers():
    router = StreamRouter([0], capacity=2, policy="block")
    assert router.offer(_event(0, pid=0))
    assert router.offer(_event(1, pid=0))
    assert not router.offer(_event(2, pid=0))  # full: caller must re-offer
    assert router.stats().refused == 1
    assert [e.seq for e in router.drain(0, max_events=1)] == [0]
    assert router.offer(_event(2, pid=0))  # space freed, lands now
    assert [e.seq for e in router.drain(0)] == [1, 2]
    with pytest.raises(ValueError, match="backpressure policy"):
        StreamRouter([0], policy="yolo")


# -- ingest / stats verbs ----------------------------------------------------


def test_ingest_ack_cursor_and_drain_update():
    client = _client()
    fit = client.fit(_reviews(n=20, seed=0), num_topics=4, base_vocab=120)
    ack1 = client.ingest(fit.handle_id, _reviews(n=3, seed=1))
    ack2 = client.ingest(fit.handle_id, _reviews(n=2, seed=2))
    assert (ack1.acked, ack1.queued) == (3, 3)
    assert (ack2.acked, ack2.queued) == (5, 5)  # cumulative + monotonic
    st = client.stats()
    assert st.ingest_queued[fit.handle_id] == 5
    assert st.ingest_acked[fit.handle_id] == 5
    assert st.total_queued == 5 and st.num_handles == 1

    upd = client.update(fit.handle_id, drain=True)
    assert upd.drained == 5 and upd.num_new_reviews == 5
    assert client.stats().total_queued == 0
    # drain + explicit reviews compose; the queue is empty so only the
    # explicit ones apply.
    upd2 = client.update(fit.handle_id, _reviews(n=2, seed=3), drain=True)
    assert upd2.drained == 0 and upd2.num_new_reviews == 2


def test_failed_drain_update_keeps_queue():
    """A rejected drain-update must not lose acked reviews: the queue is
    cleared only after the update succeeds."""
    client = _client()
    fit = client.fit(_reviews(n=15, seed=0), num_topics=4, base_vocab=120)
    client.ingest(fit.handle_id, _reviews(n=3, seed=1))
    with pytest.raises(protocol.RemoteError) as ei:
        client.update(fit.handle_id, drain=True, backend="bogus")
    assert ei.value.code == "invalid_argument"
    assert client.stats().ingest_queued[fit.handle_id] == 3
    upd = client.update(fit.handle_id, drain=True)
    assert upd.drained == 3 and upd.num_new_reviews == 3
    # And the backlog was applied exactly once, not left for a re-drain.
    assert client.stats().total_queued == 0
    # An empty drain is a no-op success: periodic flushers shouldn't have
    # to pre-check queue depth.
    noop = client.update(fit.handle_id, drain=True)
    assert noop.kind == "noop"
    assert noop.drained == 0 and noop.num_new_reviews == 0


def test_ingest_overload_rejects_batch_whole():
    client = _client(max_ingest_queue=4)
    fit = client.fit(_reviews(n=15, seed=0), num_topics=4, base_vocab=120)
    client.ingest(fit.handle_id, _reviews(n=3, seed=1))
    with pytest.raises(protocol.RemoteError) as ei:
        client.ingest(fit.handle_id, _reviews(n=2, seed=2))
    assert ei.value.code == "overloaded"
    # Nothing partial: the cursor still covers exactly the accepted batch.
    st = client.stats()
    assert st.ingest_acked[fit.handle_id] == 3
    assert st.ingest_queued[fit.handle_id] == 3
    client.update(fit.handle_id, drain=True)
    assert client.ingest(fit.handle_id, _reviews(n=2, seed=2)).acked == 5


def test_ingest_requires_known_handle_and_reviews():
    client = _client()
    with pytest.raises(protocol.RemoteError) as ei:
        client.ingest(99, _reviews(n=1))
    assert ei.value.code == "not_found"
    fit = client.fit(_reviews(n=15, seed=0), num_topics=4, base_vocab=120)
    with pytest.raises(protocol.RemoteError) as ei:
        client.ingest(fit.handle_id, [])
    assert ei.value.code == "invalid_argument"


def test_release_drops_ingest_state():
    client = _client()
    fit = client.fit(_reviews(n=15, seed=0), num_topics=4, base_vocab=120)
    client.ingest(fit.handle_id, _reviews(n=3, seed=1))
    client.release(fit.handle_id)
    st = client.stats()
    assert st.total_queued == 0 and st.ingest_acked == {}


def test_evicted_session_keeps_acked_reviews():
    """max_sessions=1 with concurrent ingest: session eviction is view-state
    only — the evicted client resyncs and not one acked review is lost."""
    server = VedaliaServer(backend="jnp", num_sweeps=4, update_sweeps=1,
                           max_sessions=1)
    a = VedaliaClient(server=server)
    fit = a.fit(_reviews(n=20, seed=0), num_topics=4, base_vocab=120)
    a.sync_view(fit.handle_id)
    old_sid = a.session_id
    acked = a.ingest(fit.handle_id, _reviews(n=4, seed=1)).acked

    b = VedaliaClient(server=server)
    b.sync_view(fit.handle_id)  # opens b's session -> evicts a's
    assert old_sid not in server.sessions

    acked = a.ingest(fit.handle_id, _reviews(n=2, seed=2)).acked
    assert acked == 6  # the cursor survived the eviction
    recovered = a.sync_view(fit.handle_id)
    assert recovered.resync and len(recovered.topics) >= 1
    upd = a.update(fit.handle_id, drain=True)
    assert upd.drained == 6 and upd.num_new_reviews == 6
    assert a.perplexity(fit.handle_id) > 0
    assert not a.sync_view(fit.handle_id).resync  # back to deltas


def test_heldout_perplexity_verb():
    client = _client()
    fit = client.fit(_reviews(n=25, seed=0), num_topics=4, base_vocab=120)
    train_ppx = client.perplexity(fit.handle_id)
    held = client.perplexity(fit.handle_id, reviews=_reviews(n=6, seed=9))
    assert np.isfinite(held) and held > 0
    assert held != pytest.approx(train_ppx)  # genuinely a different measure
    # Scoring must not mutate the model.
    assert client.perplexity(fit.handle_id) == pytest.approx(train_ppx)


# -- drift score -------------------------------------------------------------


def _topic(tid=0, prob=0.5, words=(1, 2, 3), weights=(0.5, 0.3, 0.2)):
    return TopicView(topic_id=tid, probability=prob, expected_rating=3.0,
                     expected_helpful=1.0, expected_unhelpful=0.0,
                     top_words=list(words), top_word_weights=list(weights))


def test_signature_distance_is_graded():
    t = _topic()
    sig = views_lib.topic_signature(t)
    assert views_lib.signature_distance(sig, t) == 0.0
    assert views_lib.signature_distance(None, t) == 1.0
    # A pure reorder of top words moves the score a little (Jaccard 0,
    # weights moved), nowhere near the binary topic_changed verdict.
    reordered = _topic(words=(2, 1, 3), weights=(0.5, 0.3, 0.2))
    d_reorder = views_lib.signature_distance(sig, reordered)
    assert views_lib.topic_changed(sig, reordered)  # binary: resend
    assert 0 < d_reorder < 0.3  # graded: mild drift
    # A disjoint top-word set is maximal word drift.
    swapped = _topic(words=(7, 8, 9))
    assert views_lib.signature_distance(sig, swapped) > 0.6
    assert views_lib.signature_distance(sig, swapped) <= 1.0
    # Mass shift alone scales with the relative change.
    halved = _topic(prob=0.25)
    assert 0.1 < views_lib.signature_distance(sig, halved) < 0.5


def test_view_drift_counts_removed_topics():
    view = views_lib.ModelView(topics=[_topic(tid=0)])
    sigs = {0: views_lib.topic_signature(_topic(tid=0)),
            1: views_lib.topic_signature(_topic(tid=1))}
    assert views_lib.view_drift(sigs, view) == pytest.approx(0.5)
    assert views_lib.view_drift({}, views_lib.ModelView(topics=[])) == 0.0


# -- scheduler ---------------------------------------------------------------


@pytest.fixture(scope="module")
def drift_run():
    """One full drift-policy pipeline over a concept-shifted stream."""
    events = synthetic_events(QUICK)
    router = StreamRouter([0, 1], capacity=32)
    servers = {s: VedaliaServer(backend="jnp", num_sweeps=4,
                                update_sweeps=1) for s in (0, 1)}
    clients = {s: VedaliaClient(server=servers[s]) for s in (0, 1)}
    scheduler = IncrementalScheduler(
        clients, router, microbatch=6, min_fit_reviews=8,
        staleness_budget=8.0, refit_sweeps=3, refit_policy="drift",
        fit_kwargs=dict(num_topics=4, base_vocab=QUICK.vocab_size,
                        num_sweeps=4))
    pump(events, router, scheduler, step_interval=2.0)
    return events, router, servers, clients, scheduler


def test_scheduler_fits_updates_and_refits(drift_run):
    events, router, servers, clients, scheduler = drift_run
    st = scheduler.stats
    assert st.fits >= 2  # multiple products bootstrapped
    assert st.updates >= 3
    assert st.refits >= 1  # the concept shift tripped the trigger
    assert st.refits < st.updates  # ...but not on every micro-batch
    assert st.events_applied + st.events_held_out == len(events)
    assert router.stats().total_queued == 0  # flush drained everything
    for status in scheduler.products.values():
        assert status.handle_id is not None
        assert not status.unapplied_ts and not status.pending_fit
        assert status.signatures  # drift anchor exists


def test_scheduler_staleness_budget(drift_run):
    _, _, _, _, scheduler = drift_run
    st = scheduler.stats
    assert len(st.staleness) == st.events_applied
    assert st.staleness_p(50) <= st.staleness_p(99)
    # The budget bounds how long an acked review waits; the p99 can exceed
    # it only by one step interval (the scheduler checks at step time).
    assert st.staleness_p(99) <= scheduler.staleness_budget + 2.0 + 1e-6


def test_scheduler_serves_through_shards(drift_run):
    _, _, _, clients, scheduler = drift_run
    for status in scheduler.products.values():
        view = clients[status.shard_id].sync_view(status.handle_id)
        assert view.valid
        held = status.heldout
        assert held  # the guard reservoir filled
        ppx = clients[status.shard_id].perplexity(
            status.handle_id, reviews=held)
        assert np.isfinite(ppx)


def test_refit_policy_knobs():
    with pytest.raises(ValueError, match="refit policy"):
        IncrementalScheduler({0: _client()}, StreamRouter([0]),
                             refit_policy="sometimes")
    with pytest.raises(ValueError, match="no client"):
        IncrementalScheduler({}, StreamRouter([0]))
    # base_vocab is never inferred: streamed reviews can use words the
    # bootstrap batch never saw.
    with pytest.raises(ValueError, match="base_vocab"):
        IncrementalScheduler({0: _client()}, StreamRouter([0]))
    with pytest.raises(ValueError, match="base_vocab"):
        IncrementalScheduler({0: _client()}, StreamRouter([0]),
                             fit_kwargs=dict(num_topics=4))


def test_drop_shard_rebootstraps_products_on_survivor():
    """Permanent shard loss (no snapshot): remove_shard + drop_shard
    reroutes the dead shard's products, which re-bootstrap on the
    survivor instead of ingesting into a decommissioned client."""
    spec = dataclasses.replace(QUICK, num_products=2, duration=24.0,
                               shift_at=None)
    events = synthetic_events(spec)
    router = StreamRouter([0, 1], capacity=64)
    clients = {0: _client(), 1: _client()}
    sched = IncrementalScheduler(
        clients, router, microbatch=5, min_fit_reviews=6,
        staleness_budget=6.0, refit_sweeps=2, refit_policy="never",
        fit_kwargs=dict(num_topics=4, base_vocab=spec.vocab_size,
                        num_sweeps=3))
    half = len(events) // 2
    pump(events[:half], router, sched, step_interval=2.0)
    assert {s.shard_id for s in sched.products.values()} == {0, 1}

    with pytest.raises(ValueError, match="still in the router"):
        sched.drop_shard(0)
    orphans = router.remove_shard(0)
    sched.drop_shard(0)
    for e in orphans:
        assert router.offer(e)
    pump(events[half:], router, sched, step_interval=2.0)

    statuses = list(sched.products.values())
    assert all(s.shard_id == 1 for s in statuses)  # all rerouted
    assert all(s.handle_id is not None for s in statuses)  # re-bootstrapped
    assert clients[1].stats().num_handles == len(statuses)
    for s in statuses:
        assert clients[1].sync_view(s.handle_id).valid


def test_oversized_ingest_batch_is_chunked():
    """One dispatch bigger than the server's whole ingest queue must land
    (chunked + fold-and-retry), not crash on `overloaded`."""
    server = VedaliaServer(backend="jnp", num_sweeps=3, update_sweeps=1,
                           max_ingest_queue=4)
    client = VedaliaClient(server=server)
    router = StreamRouter([0], capacity=64)
    sched = IncrementalScheduler(
        {0: client}, router, microbatch=50, min_fit_reviews=6,
        staleness_budget=100.0, refit_policy="never", heldout_every=1000,
        fit_kwargs=dict(num_topics=4, base_vocab=120, num_sweeps=3))
    events = [_event(i, pid=0, t=0.1 * i) for i in range(20)]
    for e in events[:6]:  # bootstrap fit
        assert router.offer(e)
    sched.step(1.0)
    for e in events[6:]:  # one 14-review dispatch vs a 4-slot queue
        assert router.offer(e)
    sched.step(2.0)
    status = sched.products[0]
    assert status.acked == 14
    assert sched.stats.overloaded_retries >= 1
    sched.flush(3.0)
    assert client.stats().total_queued == 0
    assert sched.stats.events_applied == 20


def test_always_and_never_policies():
    spec = dataclasses.replace(QUICK, num_products=1, duration=15.0,
                               shift_at=None)
    events = synthetic_events(spec)

    def run(policy):
        router = StreamRouter([0], capacity=32)
        sched = IncrementalScheduler(
            {0: _client()}, router, microbatch=5, min_fit_reviews=6,
            staleness_budget=6.0, refit_sweeps=2, refit_policy=policy,
            fit_kwargs=dict(num_topics=4, base_vocab=spec.vocab_size,
                            num_sweeps=3))
        pump(events, router, sched, step_interval=2.0)
        return sched.stats

    always, never = run("always"), run("never")
    assert always.refits == always.updates > 0
    assert never.refits == 0 and never.updates == always.updates


# -- snapshot / restore ------------------------------------------------------


def test_snapshot_roundtrip_is_codec_exact(drift_run):
    _, _, servers, _, _ = drift_run
    for sid, server in servers.items():
        snap = snapshot_server(server)
        blob = json.dumps(snap)  # must be pure JSON
        restored = restore_server(json.loads(blob))
        assert snapshot_server(restored) == snap, f"shard {sid} mismatch"
        assert sorted(restored.service.handles) \
            == sorted(server.service.handles)
        assert restored.ingest_acked == server.ingest_acked
        # Id counters survive too: a restored server must never re-mint a
        # session/cursor id a pre-kill client still holds.
        assert restored._next_session == server._next_session
        assert restored._next_cursor == server._next_cursor


def test_snapshot_preserves_pending_ingest():
    client = _client()
    fit = client.fit(_reviews(n=15, seed=0), num_topics=4, base_vocab=120)
    client.ingest(fit.handle_id, _reviews(n=3, seed=1))
    snap = snapshot_server(client.server)
    restored = restore_server(snap)
    client.rebind(server=restored)
    # Acked-but-unapplied reviews survived the kill.
    assert client.stats().ingest_queued[fit.handle_id] == 3
    upd = client.update(fit.handle_id, drain=True)
    assert upd.drained == 3 and upd.num_new_reviews == 3


def test_clients_recover_from_restore_via_resync(drift_run):
    _, _, servers, clients, scheduler = drift_run
    sid = 0
    status = next(s for s in scheduler.products.values()
                  if s.shard_id == sid)
    client = clients[sid]
    assert not client.sync_view(status.handle_id).resync  # warm deltas
    restored = restore_server(snapshot_server(servers[sid]))
    client.rebind(server=restored)
    recovered = client.sync_view(status.handle_id)  # old session + cursor
    assert recovered.resync and len(recovered.topics) >= 1
    assert not client.sync_view(status.handle_id).resync  # deltas resume
    # The restored model still updates and serves.
    upd = client.update(status.handle_id, _reviews(n=2, seed=42))
    assert upd.num_new_reviews == 2


def test_snapshot_restores_backend_opts():
    server = VedaliaServer(backend="jnp", num_sweeps=3, update_sweeps=1,
                           backend_opts={"alias": {"mh_steps": 2}})
    client = VedaliaClient(server=server)
    client.fit(_reviews(n=15, seed=0), num_topics=4, base_vocab=120)
    snap = snapshot_server(server)
    restored = restore_server(json.loads(json.dumps(snap)))
    assert restored.service._backend_opts == {"alias": {"mh_steps": 2}}
    assert snapshot_server(restored) == snap


def test_restore_rejects_unknown_format():
    with pytest.raises(ValueError, match="snapshot format"):
        restore_server({"format": 999})


def test_rebind_argument_validation():
    client = _client()
    with pytest.raises(ValueError, match="exactly one"):
        client.rebind()
    with pytest.raises(ValueError, match="exactly one"):
        client.rebind(lambda s: s, server=client.server)
