"""`repro.api` service layer: backend parity, service round-trip, shims.

Covers the PR-1 acceptance gates:
  * the jnp / pallas / distributed backends agree on count invariants and
    land within a perplexity tolerance of the jnp oracle;
  * `VedaliaService` fit -> update -> view -> validate() round-trips;
  * legacy module-level entry points (`gibbs.run`, `update.add_documents`)
    still work and match the new API bit-for-bit where they share a path.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    VedaliaService,
    available_backends,
    backend_capabilities,
    codec,
    get_backend,
    select_backend,
)
from repro.api.service import FitRequest
from repro.core import gibbs, perplexity, update
from repro.core.types import Corpus, LDAConfig, init_state
from repro.data import reviews

BACKENDS = ("jnp", "pallas", "distributed", "pserver", "alias", "sparse")


def _corpus(n=3000, v=120, d=40, k=8, w_bits=None, weighted=True, seed=0):
    rng = np.random.default_rng(seed)
    cfg = LDAConfig(num_topics=k, vocab_size=v, num_docs=d, w_bits=w_bits)
    wts = rng.random(n).astype(np.float32) if weighted else np.ones(
        n, np.float32)
    corpus = Corpus(
        docs=jnp.asarray(rng.integers(0, d, n), jnp.int32),
        words=jnp.asarray(rng.integers(0, v, n), jnp.int32),
        weights=jnp.asarray(wts),
    )
    return cfg, corpus


def _reviews(n=50, vocab=120, seed=0):
    corp = reviews.generate(reviews.SyntheticSpec(
        num_reviews=n, vocab_size=vocab, num_topics=4, mean_tokens=30,
        seed=seed))
    return corp.reviews


# -- registry ---------------------------------------------------------------


def test_registry_lists_all_backends():
    assert set(BACKENDS) <= set(available_backends())


def test_unknown_backend_raises_with_choices():
    with pytest.raises(KeyError, match="distributed"):
        get_backend("cuda")


def test_backend_capabilities_metadata():
    caps = backend_capabilities()
    assert set(BACKENDS) <= set(caps)
    assert caps["sparse"].device_kind == "phone"
    assert caps["distributed"].device_kind == "pod"
    assert caps["pserver"].device_kind == "pod"
    assert caps["alias"].proposal_based and not caps["jnp"].proposal_based
    for name in BACKENDS:  # every backend declares the full record
        assert caps[name].warm_start and caps[name].weighted
    assert backend_capabilities("jnp") is caps["jnp"]
    with pytest.raises(KeyError, match="available"):
        backend_capabilities("cuda")


def test_auto_selector_routes_by_workload():
    assert select_backend(device_kind="phone") == "sparse"
    assert select_backend(device_kind="pod") == "pserver"
    assert select_backend(device_kind="tpu") == "jnp"
    assert select_backend(task="update", num_tokens=10**7) == "jnp"
    assert select_backend(task="fit", num_tokens=10**6) == "alias"
    assert select_backend(task="fit", num_tokens=500) == "jnp"
    # Routing degrades gracefully when a preferred backend is unregistered:
    # a pod without the pserver tier falls back to the replicated oracle.
    assert select_backend(num_tokens=10**6,
                          available=["jnp", "pallas"]) == "jnp"
    assert select_backend(device_kind="pod",
                          available=["jnp", "distributed"]) == "distributed"


def test_auto_selector_multi_model_wins_within_device_class():
    """Regression: an explicit device_kind used to shadow `num_models` and
    silently serialize coalesced refits. Multi-model work must stay on the
    stacked sweep whenever the batched backend serves that device class."""
    assert select_backend(device_kind="tpu", num_models=4) == "batched"
    assert select_backend(device_kind="tpu", num_models=2,
                          task="update") == "batched"
    # Other device classes have no batched equivalent: the device pick wins
    # (pod work must not silently serialize onto the tpu-class batched
    # sweep — it stays on the sharded pserver tier).
    assert select_backend(device_kind="phone", num_models=4) == "sparse"
    assert select_backend(device_kind="pod", num_models=4) == "pserver"
    # Degrades to the device pick when batched is unavailable.
    assert select_backend(device_kind="tpu", num_models=4,
                          available=["jnp", "alias"]) == "jnp"
    # Single-model explicit-device routing is unchanged.
    assert select_backend(device_kind="tpu", num_models=1) == "jnp"


def test_alias_sampler_path_knob():
    """AliasSampler mirrors BatchedSampler's path selector; bad paths fail
    loudly at construction."""
    assert get_backend("alias", path="jnp")._path() == "jnp"
    assert get_backend("alias", path="pallas")._path() == "pallas"
    assert get_backend("alias")._path() in ("jnp", "pallas")  # auto resolves
    with pytest.raises(ValueError, match="alias path"):
        get_backend("alias", path="cuda")


def test_service_resolves_auto_backend():
    svc = VedaliaService(backend="auto", num_sweeps=4)
    handle = svc.fit(_reviews(n=20, seed=0), num_topics=4, base_vocab=120)
    assert handle.backend == "jnp"  # small fit routes to the oracle
    phone = svc.fit(_reviews(n=15, seed=1), num_topics=4, base_vocab=120,
                    num_sweeps=2, device_kind="phone")
    assert phone.backend == "sparse"
    resp = svc.update(handle, _reviews(n=6, seed=2), backend="auto")
    assert handle.backend == "jnp"
    assert np.isfinite(resp.perplexity)


# -- backend parity (acceptance gate) ---------------------------------------


@pytest.mark.parametrize("w_bits", [None, 8])
def test_backend_count_invariants(w_bits):
    """All backends conserve total weighted mass and per-word masses."""
    cfg, corpus = _corpus(w_bits=w_bits)
    w = np.asarray(corpus.weights, np.float64)
    word_mass = np.bincount(np.asarray(corpus.words), weights=w,
                            minlength=cfg.vocab_size)
    doc_mass = np.bincount(np.asarray(corpus.docs), weights=w,
                           minlength=cfg.num_docs)
    for name in BACKENDS:
        st = get_backend(name).run(cfg, corpus, jax.random.PRNGKey(0), 3)
        n_dt, n_wt, n_t = (np.asarray(a, np.float64) for a in
                           codec.decode_counts(cfg, st))
        tol = 1e-2 if w_bits is None else corpus.num_tokens * 2.0 ** -(
            w_bits + 1)
        np.testing.assert_allclose(n_t.sum(), w.sum(), atol=tol,
                                   err_msg=name)
        np.testing.assert_allclose(n_wt.sum(axis=1), word_mass, atol=0.02,
                                   err_msg=name)
        np.testing.assert_allclose(n_dt.sum(axis=1), doc_mass, atol=0.02,
                                   err_msg=name)


def test_backend_perplexity_parity_with_oracle():
    """After N sweeps all backends land within tolerance of the jnp oracle
    (stochastically independent chains on a planted-structure corpus)."""
    revs = _reviews(n=60, vocab=120)
    from repro.core import rlda

    prep = rlda.prepare(revs, base_vocab=120, num_topics=8, w_bits=8)
    sweeps = 15
    perps = {}
    for name in BACKENDS:
        st = get_backend(name).run(
            prep.cfg, prep.corpus, jax.random.PRNGKey(7), sweeps)
        perps[name] = float(perplexity.perplexity(prep.cfg, st, prep.corpus))
    for name in ("pallas", "distributed", "pserver"):
        assert abs(np.log(perps[name]) - np.log(perps["jnp"])) < 0.2, perps


def test_fast_sampler_perplexity_parity_with_oracle():
    """The paper's compatibility claim (§3.1): SparseLDA and AliasLDA fit
    RLDA corpora to the same quality region as the exact parallel sweep.
    Budgets are mixing-matched, not sweep-matched — the sequential sampler
    uses fresh counts within a sweep, the MH sampler needs more sweeps to
    burn through its stale proposals."""
    revs = _reviews(n=60, vocab=120)
    from repro.core import rlda

    prep = rlda.prepare(revs, base_vocab=120, num_topics=8, w_bits=8)
    budgets = {"jnp": 30, "sparse": 15, "alias": 100}
    perps = {}
    for name, sweeps in budgets.items():
        st = get_backend(name).run(
            prep.cfg, prep.corpus, jax.random.PRNGKey(7), sweeps)
        perps[name] = float(perplexity.perplexity(prep.cfg, st, prep.corpus))
    for name in ("sparse", "alias"):
        assert abs(np.log(perps[name]) - np.log(perps["jnp"])) < 0.3, perps


@pytest.mark.parametrize("backend", ["alias", "sparse"])
def test_fast_sampler_codec_roundtrip_w8(backend):
    """alias/sparse speak stored state: at w_bits=8 they must emit int32
    fixed point that survives an encode(decode(.)) round trip and decodes
    to the exact weighted-count invariants."""
    cfg, corpus = _corpus(n=1200, d=30, w_bits=8)
    st = get_backend(backend).run(cfg, corpus, jax.random.PRNGKey(3), 2)
    assert st.n_wt.dtype == jnp.int32 and st.n_dt.dtype == jnp.int32
    st2 = codec.encode_state(cfg, codec.decode_state(cfg, st))
    for a, b in ((st.n_dt, st2.n_dt), (st.n_wt, st2.n_wt), (st.n_t, st2.n_t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # Stored counts decode to the same totals the corpus carries.
    _, n_wt, _ = codec.decode_counts(cfg, st)
    tol = corpus.num_tokens * 2.0 ** -9
    np.testing.assert_allclose(
        float(n_wt.sum()), float(np.asarray(corpus.weights).sum()), atol=tol)


def test_alias_fit_is_updatable_by_jnp_midrun():
    """Acceptance gate: a model fit by the proposal-based backend is
    refined and incrementally updated by the exact oracle mid-run."""
    svc = VedaliaService(backend="alias", num_sweeps=10, update_sweeps=1)
    handle = svc.fit(_reviews(n=30, seed=0), num_topics=4, base_vocab=120,
                     w_bits=8)
    assert handle.backend == "alias"
    svc.refine(handle, num_sweeps=2, backend="jnp")
    assert handle.backend == "jnp"
    resp = svc.update(handle, _reviews(n=8, seed=4), backend="jnp")
    assert np.isfinite(resp.perplexity)
    assert handle.num_reviews == 38
    assert svc.view(handle).valid


def test_sparse_backend_serves_through_service():
    """The 'phone' path end-to-end: fit + update + view through sparse."""
    svc = VedaliaService(backend="sparse", num_sweeps=5, update_sweeps=1)
    handle = svc.fit(_reviews(n=20, seed=0), num_topics=4, base_vocab=120,
                     w_bits=8)
    resp = svc.update(handle, _reviews(n=5, seed=2))
    assert np.isfinite(resp.perplexity)
    assert svc.view(handle).valid


def test_pallas_backend_matches_oracle_scores():
    """Same counts + same gumbel noise => the kernel's block scores must
    reproduce the oracle's argmax exactly (the parity gate for putting the
    kernel on the production path)."""
    from repro.core.gibbs import resample_block
    from repro.kernels.lda_gibbs.kernel import gibbs_resample_blocked

    rng = np.random.default_rng(3)
    n, k = 512, 128
    cfg = LDAConfig(num_topics=k, vocab_size=64, num_docs=16)
    docs = jnp.asarray(rng.integers(0, 16, n), jnp.int32)
    words = jnp.asarray(rng.integers(0, 64, n), jnp.int32)
    z = jnp.asarray(rng.integers(0, k, n), jnp.int32)
    wts = jnp.asarray(rng.random(n), jnp.float32)
    n_dt = jnp.asarray(rng.integers(0, 40, (16, k)), jnp.float32)
    n_wt = jnp.asarray(rng.integers(0, 40, (64, k)), jnp.float32)
    n_t = n_wt.sum(0)
    g = jax.random.gumbel(jax.random.PRNGKey(0), (n, k), jnp.float32)

    z_oracle = resample_block(cfg, docs, words, z, wts, n_dt, n_wt, n_t, g)
    z_kernel = gibbs_resample_blocked(
        n_dt[docs], n_wt[words], n_t, z, wts, g,
        alpha=cfg.alpha, beta=cfg.beta, beta_bar=cfg.beta_bar,
        token_block=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(z_oracle), np.asarray(z_kernel))


# -- service round-trip -----------------------------------------------------


def test_service_fit_update_view_roundtrip():
    svc = VedaliaService(backend="jnp", num_sweeps=10, update_sweeps=2)
    handle = svc.fit(_reviews(n=50, seed=0), num_topics=6, base_vocab=120,
                     w_bits=8)
    assert handle.num_reviews == 50
    p0 = svc.perplexity(handle)
    assert np.isfinite(p0)

    resp = svc.update(handle, _reviews(n=15, seed=1))
    assert resp.kind == "incremental"
    assert handle.num_reviews == 65
    assert len(handle.prep.helpful) == 65  # metadata grew with the corpus

    view = svc.view(handle, top_n=6, max_topics=4)
    assert view.valid and view.view.validate()
    assert 1 <= len(view.topic_ids) <= 4
    assert view.payload_bytes == len(view.payload) > 0

    top = svc.top_reviews(handle, view.topic_ids[0], n=3)
    assert len(top.review_ids) == 3
    assert all(0 <= d < 65 for d in top.review_ids)


def test_service_periodic_full_recompute():
    svc = VedaliaService(backend="jnp", num_sweeps=5, update_sweeps=1)
    handle = svc.fit(_reviews(n=30, seed=0), num_topics=4, base_vocab=120)
    handle.model.full_recompute_every = 2
    kinds = [svc.update(handle, _reviews(n=8, seed=2 + i)).kind
             for i in range(2)]
    assert kinds == ["incremental", "full_recompute"]


@pytest.mark.parametrize("backend", ["pallas", "distributed", "pserver"])
def test_service_fit_on_alternate_backends(backend):
    """The acceptance path: fit + view through each non-oracle backend."""
    svc = VedaliaService(backend=backend, num_sweeps=6)
    handle = svc.fit(_reviews(n=30, seed=0), num_topics=6, base_vocab=120,
                     w_bits=8)
    assert handle.backend == backend
    assert np.isfinite(svc.perplexity(handle))
    view = svc.view(handle, top_n=5)
    assert view.valid


def test_cross_backend_refine_and_update():
    """A model fit by one backend is updated/refined by another — the
    stored-state codec makes backends interchangeable mid-run."""
    svc = VedaliaService(backend="jnp", num_sweeps=6, update_sweeps=1)
    handle = svc.fit(_reviews(n=30, seed=0), num_topics=4, base_vocab=120,
                     w_bits=8)
    svc.refine(handle, num_sweeps=2, backend="pallas")
    assert handle.backend == "pallas"
    resp = svc.update(handle, _reviews(n=8, seed=5))  # pallas-backed update
    assert np.isfinite(resp.perplexity)
    assert svc.view(handle).valid


# -- legacy shims -----------------------------------------------------------


def test_jnp_backend_is_bitwise_gibbs_run():
    """get_backend('jnp').run IS the legacy gibbs.run fast path."""
    cfg, corpus = _corpus(n=2000, w_bits=8)
    st_new = get_backend("jnp").run(cfg, corpus, jax.random.PRNGKey(5), 4)
    st_old = gibbs.run(cfg, corpus, jax.random.PRNGKey(5), 4)
    np.testing.assert_array_equal(np.asarray(st_new.z), np.asarray(st_old.z))
    np.testing.assert_array_equal(np.asarray(st_new.n_wt),
                                  np.asarray(st_old.n_wt))


def test_add_documents_default_sampler_unchanged():
    """update.add_documents with no sampler arg == explicit jnp backend."""
    cfg, corpus = _corpus(n=1500, d=30, w_bits=8)
    state = codec.encode_state(
        cfg, init_state(cfg, corpus, jax.random.PRNGKey(0)))
    rng = np.random.default_rng(1)
    new_docs = np.repeat(np.arange(30, 34), 20)
    new_words = rng.integers(0, cfg.vocab_size, len(new_docs))
    new_wts = np.ones(len(new_docs), np.float32)

    def make():
        return update.UpdatableModel(
            cfg=cfg, corpus=corpus,
            state=jax.tree_util.tree_map(lambda x: x, state))

    m_default = update.add_documents(
        make(), new_docs, new_words, new_wts, jax.random.PRNGKey(9))
    m_jnp = update.add_documents(
        make(), new_docs, new_words, new_wts, jax.random.PRNGKey(9),
        sampler=get_backend("jnp"))
    np.testing.assert_array_equal(np.asarray(m_default.state.z),
                                  np.asarray(m_jnp.state.z))
    assert m_default.cfg.num_docs == 34


def test_codec_roundtrip_and_rebuild():
    cfg, corpus = _corpus(n=1000, w_bits=8)
    st = codec.rebuild_state(
        cfg, corpus, jnp.zeros(corpus.num_tokens, jnp.int32))
    assert st.n_wt.dtype == jnp.int32  # stored fixed point
    n_dt, n_wt, n_t = codec.decode_counts(cfg, st)
    assert n_wt.dtype == jnp.float32
    # encode(decode(x)) is the identity on stored states
    st2 = codec.encode_state(cfg, codec.decode_state(cfg, st))
    np.testing.assert_array_equal(np.asarray(st.n_wt), np.asarray(st2.n_wt))
    # numpy decode agrees with jnp decode
    n_dt_np, n_wt_np, n_t_np = codec.decode_counts_np(cfg, st)
    np.testing.assert_allclose(n_wt_np, np.asarray(n_wt), atol=1e-6)


# -- TopicEngine ------------------------------------------------------------


def test_topic_engine_serves_bucketed_products():
    from repro.serving import TopicEngine

    eng = TopicEngine(max_batch=2, num_sweeps=4)
    for uid in range(3):
        eng.submit(FitRequest(
            uid=uid, reviews=_reviews(n=25, seed=uid),
            num_topics=6 if uid < 2 else 8, base_vocab=120, num_sweeps=4))
    results = {r.uid: r for r in eng.run()}
    assert set(results) == {0, 1, 2}
    assert eng.pending() == 0
    for uid, r in results.items():
        assert r.view.valid, uid
        assert np.isfinite(r.perplexity)
        assert r.view.cursor is not None  # views crossed the protocol
    assert results[2].fit.num_topics == 8
    assert len({r.handle_id for r in results.values()}) == 3


def test_topic_engine_rejects_empty_request():
    from repro.serving import TopicEngine

    eng = TopicEngine(num_sweeps=2)
    with pytest.raises(ValueError, match="empty review set"):
        eng.submit(FitRequest(uid=0, reviews=[]))


def test_update_with_tokenless_trailing_review_keeps_alignment():
    """A trailing zero-token review must still count as a document: prep
    metadata, cfg.num_docs, and the merged corpus stay aligned so views
    keep working (regression: doc count was inferred from token ids)."""
    from repro.core.rlda import Review

    svc = VedaliaService(backend="jnp", num_sweeps=5, update_sweeps=1)
    handle = svc.fit(_reviews(n=30, seed=0), num_topics=4, base_vocab=120)
    new = _reviews(n=5, seed=9)
    new.append(Review(tokens=np.array([], np.int32), rating=3.0, user=0,
                      helpful=0, unhelpful=0, writing_quality=0.5))
    resp = svc.update(handle, new)
    assert resp.num_new_reviews == 6
    assert handle.cfg.num_docs == 36
    assert len(handle.prep.helpful) == 36
    # prep.corpus tracks the merged corpus, not the original fit corpus
    assert handle.prep.corpus.num_tokens == handle.model.corpus.num_tokens
    assert svc.view(handle).valid
    assert len(svc.top_reviews(handle, 0, n=3).review_ids) == 3
