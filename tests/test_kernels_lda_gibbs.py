"""lda_gibbs Pallas kernel vs pure-jnp oracle: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fractional, gibbs, perplexity
from repro.core.types import Corpus, LDAConfig, init_state
from repro.kernels.lda_gibbs import ops as kops
from repro.kernels.lda_gibbs.kernel import (
    gibbs_resample_blocked,
    gibbs_resample_blocked_batched,
)
from repro.kernels.lda_gibbs.ref import resample_tile


def _random_counts(rng, n, k, dtype):
    return jnp.asarray(rng.integers(0, 50, (n, k)).astype(dtype))


@pytest.mark.parametrize("n,k,token_block", [
    (256, 128, 256), (512, 128, 256), (1024, 256, 256),
    (512, 384, 128), (256, 128, 64),
])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_kernel_matches_ref_sweep(n, k, token_block, dtype):
    rng = np.random.default_rng(int(n + k))
    w_bits = 8 if dtype == np.int32 else None
    rows_d = _random_counts(rng, n, k, dtype)
    rows_w = _random_counts(rng, n, k, dtype)
    tot = jnp.asarray(rng.integers(1, 500, k).astype(dtype))
    z = jnp.asarray(rng.integers(0, k, n).astype(np.int32))
    wts = jnp.asarray((rng.random(n) * (rng.random(n) > 0.1)).astype(np.float32))
    g = jax.random.gumbel(jax.random.PRNGKey(0), (n, k), jnp.float32)

    out = gibbs_resample_blocked(
        rows_d, rows_w, tot, z, wts, g,
        alpha=0.1, beta=0.01, beta_bar=0.01 * k, w_bits=w_bits,
        token_block=token_block, interpret=True,
    )
    if w_bits is not None:
        scale = fractional.precision(w_bits)
        rd = rows_d.astype(jnp.float32) * scale
        rw = rows_w.astype(jnp.float32) * scale
        tt = tot.astype(jnp.float32) * scale
    else:
        rd, rw, tt = rows_d, rows_w, tot
    ref = resample_tile(rd, rw, tt, z, wts, g, 0.1, 0.01, 0.01 * k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def _corpus(rng, n, v, d):
    return Corpus(
        docs=jnp.asarray(rng.integers(0, d, n), jnp.int32),
        words=jnp.asarray(rng.integers(0, v, n), jnp.int32),
        weights=jnp.asarray(rng.random(n).astype(np.float32)),
    )


@pytest.mark.parametrize("w_bits", [None, 8])
def test_ops_sweep_matches_system_gibbs_statistics(w_bits):
    """Kernel-path sweep and system (pure-jnp) sweep see the same scores:
    with identical gumbel they must produce identical assignments; here we
    check distributional equivalence via converged perplexity instead."""
    rng = np.random.default_rng(0)
    cfg = LDAConfig(num_topics=12, vocab_size=150, num_docs=40, w_bits=w_bits)
    corpus = _corpus(rng, 3000, 150, 40)

    st_sys = gibbs.run(cfg, corpus, jax.random.PRNGKey(1), num_sweeps=20)
    st_k = gibbs.run(cfg, corpus, jax.random.PRNGKey(2), num_sweeps=0)
    st_k = init_state(cfg, corpus, jax.random.PRNGKey(2))
    if w_bits is not None:
        from repro.core.types import LDAState

        st_k = LDAState(
            z=st_k.z,
            n_dt=fractional.to_fixed(st_k.n_dt, w_bits),
            n_wt=fractional.to_fixed(st_k.n_wt, w_bits),
            n_t=fractional.to_fixed(st_k.n_t, w_bits),
        )
    for i in range(20):
        st_k = kops.sweep(cfg, st_k, corpus, jax.random.PRNGKey(100 + i))
    p_sys = perplexity.perplexity(cfg, st_sys, corpus)
    p_k = perplexity.perplexity(cfg, st_k, corpus)
    assert abs(np.log(p_sys) - np.log(p_k)) < 0.25, (p_sys, p_k)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_batched_kernel_matches_ref_per_model(dtype):
    """The model-grid kernel is M independent single-model tiles: each grid
    step must index its own model's count rows, preserve exact
    self-exclusion, and honor w_bits fixed-point rescaling."""
    rng = np.random.default_rng(11)
    m, n, k, token_block = 3, 512, 128, 256
    w_bits = 8 if dtype == np.int32 else None
    rows_d = jnp.asarray(rng.integers(0, 50, (m, n, k)).astype(dtype))
    rows_w = jnp.asarray(rng.integers(0, 50, (m, n, k)).astype(dtype))
    tot = jnp.asarray(rng.integers(1, 500, (m, k)).astype(dtype))
    z = jnp.asarray(rng.integers(0, k, (m, n)).astype(np.int32))
    wts = jnp.asarray(
        (rng.random((m, n)) * (rng.random((m, n)) > 0.1)).astype(np.float32))
    g = jax.random.gumbel(jax.random.PRNGKey(2), (m, n, k), jnp.float32)

    out = gibbs_resample_blocked_batched(
        rows_d, rows_w, tot, z, wts, g,
        alpha=0.1, beta=0.01, beta_bar=0.01 * k, w_bits=w_bits,
        token_block=token_block, interpret=True,
    )
    assert out.shape == (m, n)
    for i in range(m):
        if w_bits is not None:
            scale = fractional.precision(w_bits)
            rd = rows_d[i].astype(jnp.float32) * scale
            rw = rows_w[i].astype(jnp.float32) * scale
            tt = tot[i].astype(jnp.float32) * scale
        else:
            rd, rw, tt = rows_d[i], rows_w[i], tot[i]
        ref = resample_tile(rd, rw, tt, z[i], wts[i], g[i],
                            0.1, 0.01, 0.01 * k)
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(ref))


@pytest.mark.parametrize("w_bits", [None, 8])
def test_ops_sweep_many_matches_single_model_sweeps(w_bits):
    """Full batched kernel sweep (gather + model-grid kernel + vmapped
    rebuild) == the single-model kernel sweep per model, bit for bit."""
    m = 3
    cfg = LDAConfig(num_topics=12, vocab_size=150, num_docs=40,
                    w_bits=w_bits)
    corpora = [_corpus(np.random.default_rng(40 + i), 600, 150, 40)
               for i in range(m)]
    stacked = Corpus(
        docs=jnp.stack([c.docs for c in corpora]),
        words=jnp.stack([c.words for c in corpora]),
        weights=jnp.stack([c.weights for c in corpora]),
    )
    keys = jax.random.split(jax.random.PRNGKey(9), m)
    states = jax.vmap(
        lambda co, k: init_state(cfg, co, k))(stacked, keys)
    if w_bits is not None:
        from repro.core.types import LDAState

        states = LDAState(
            z=states.z,
            n_dt=fractional.to_fixed(states.n_dt, w_bits),
            n_wt=fractional.to_fixed(states.n_wt, w_bits),
            n_t=fractional.to_fixed(states.n_t, w_bits),
        )
    out = kops.sweep_many(cfg, states, stacked, keys)
    for i in range(m):
        st_i = jax.tree_util.tree_map(lambda x: x[i], states)
        ref = kops.sweep(cfg, st_i, corpora[i], keys[i])
        np.testing.assert_array_equal(np.asarray(out.z[i]),
                                      np.asarray(ref.z))
        np.testing.assert_array_equal(np.asarray(out.n_wt[i]),
                                      np.asarray(ref.n_wt))


def test_kernel_keeps_padding_assignments():
    rng = np.random.default_rng(3)
    n, k = 256, 128
    rows = _random_counts(rng, n, k, np.float32)
    z = jnp.asarray(rng.integers(0, k, n).astype(np.int32))
    wts = jnp.zeros(n, jnp.float32)  # all padding
    g = jax.random.gumbel(jax.random.PRNGKey(0), (n, k), jnp.float32)
    out = gibbs_resample_blocked(
        rows, rows, jnp.ones(k), z, wts, g,
        alpha=0.1, beta=0.01, beta_bar=1.28, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(z))
