"""QuantSpec codec redesign: packing properties, wire interop, views.

Covers the quantized state & wire format acceptance gates:
  * int8/int4 row packing round-trips within the scale/2 bound (all-zero
    rows exact, per-row scale extremes, odd-length nibble packing);
  * `fixed` mode is bit-exact against the pre-QuantSpec `w_bits` path
    from identical keys (live state never packs);
  * wire interop: a quantized-capable client against a pre-quant server
    (raw form unchanged) and quantized payloads decoding on request;
  * `view_version` round-trip + typed `ViewVersionError` resync;
  * quantized view / export / spot-check / adopt end-to-end through
    `VedaliaClient`;
  * the packed kernel paths (gibbs + alias MH) run and land near the
    unquantized sweep.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import VedaliaClient, codec as api_codec, protocol
from repro.core import codec, gibbs, quant
from repro.core.quant import QuantSpec
from repro.core.types import Corpus, LDAConfig, init_state
from repro.core.views import (
    ModelView,
    TopicView,
    ViewVersionError,
    VIEW_VERSION,
)
from repro.data import reviews


def _corpus(n=2000, v=96, d=30, k=8, w_bits=None, quant_spec=None, seed=0):
    rng = np.random.default_rng(seed)
    cfg = LDAConfig(num_topics=k, vocab_size=v, num_docs=d, w_bits=w_bits,
                    quant=quant_spec)
    corpus = Corpus(
        docs=jnp.asarray(rng.integers(0, d, n), jnp.int32),
        words=jnp.asarray(rng.integers(0, v, n), jnp.int32),
        weights=jnp.asarray(rng.random(n), jnp.float32),
    )
    return cfg, corpus


def _reviews(n=60, vocab=120, seed=0):
    return reviews.generate(reviews.SyntheticSpec(
        num_reviews=n, vocab_size=vocab, num_topics=4, mean_tokens=30,
        seed=seed)).reviews


# -- QuantSpec semantics ------------------------------------------------------


def test_spec_validation_and_properties():
    assert QuantSpec.f32().live_mode == "f32"
    assert QuantSpec.fixed(8).live_fixed
    assert QuantSpec.int8().bits == 8
    assert QuantSpec.int4(w_bits=8).bits == 4
    assert QuantSpec.int4(w_bits=8).live_fixed  # packed + fixed live state
    with pytest.raises(ValueError, match="unknown quant mode"):
        QuantSpec(mode="bf16")
    with pytest.raises(ValueError, match="requires w_bits"):
        QuantSpec(mode="fixed")
    with pytest.raises(ValueError, match="must not carry"):
        QuantSpec(mode="f32", w_bits=4)
    with pytest.raises(ValueError, match="wire quant mode"):
        QuantSpec.from_wire("fixed")
    assert QuantSpec.from_w_bits(None) == QuantSpec.f32()
    assert QuantSpec.from_w_bits(8) == QuantSpec.fixed(8)


def test_spec_is_hashable_and_cfg_stays_static():
    # The spec rides inside LDAConfig through jit static args.
    cfg = LDAConfig(num_topics=4, vocab_size=16, num_docs=4,
                    quant=QuantSpec.int8())
    assert hash(cfg) == hash(cfg)
    assert cfg.quant_spec is cfg.quant
    legacy = LDAConfig(num_topics=4, vocab_size=16, num_docs=4, w_bits=6)
    assert legacy.quant_spec == QuantSpec.fixed(6)


def test_codec_for_caches_per_spec():
    cfg_a = LDAConfig(num_topics=4, vocab_size=16, num_docs=4, w_bits=8)
    cfg_b = LDAConfig(num_topics=8, vocab_size=32, num_docs=8, w_bits=8)
    assert codec.codec_for(cfg_a) is codec.codec_for(cfg_b)
    assert codec.codec_for(cfg_a).spec == QuantSpec.fixed(8)


# -- packing round-trip properties -------------------------------------------


@given(
    bits=st.integers(min_value=0, max_value=1),
    k=st.integers(min_value=1, max_value=33),
    rows=st.integers(min_value=1, max_value=8),
    scale=st.floats(min_value=1e-6, max_value=1e6, allow_nan=False),
)
@settings(max_examples=100, deadline=None)
def test_roundtrip_error_within_half_scale(bits, k, rows, scale):
    bits = 4 if bits else 8
    rng = np.random.default_rng(k * 1000 + rows)
    x = (rng.random((rows, k)) * scale).astype(np.float32)
    codes, scales = quant.quantize_rows(x, bits)
    back = quant.dequantize_rows(codes, scales, bits, k)
    assert back.shape == x.shape
    # rint can land half a step away; float32 rounding adds a hair more.
    tol = scales[:, None] * 0.5 + 1e-5 * np.abs(x) + 1e-30
    assert np.all(np.abs(back - x) <= tol)


def test_all_zero_rows_decode_exactly():
    x = np.zeros((3, 7), np.float32)
    for bits in (4, 8):
        codes, scales = quant.quantize_rows(x, bits)
        assert np.all(scales == 0.0)
        assert np.array_equal(
            quant.dequantize_rows(codes, scales, bits, 7), x)
    # Mixed: one live row between zero rows keeps its own scale.
    x[1, 3] = 5.0
    codes, scales = quant.quantize_rows(x, 8)
    back = quant.dequantize_rows(codes, scales, 8, 7)
    assert np.array_equal(back[0], np.zeros(7))
    assert np.array_equal(back[2], np.zeros(7))
    assert abs(back[1, 3] - 5.0) <= scales[1] / 2 + 1e-6


def test_rowmax_is_exact_per_row():
    # The top entry of every row hits code == levels, decoding to rowmax.
    rng = np.random.default_rng(3)
    x = rng.random((5, 12)).astype(np.float32) * np.asarray(
        [1e-5, 1.0, 37.0, 1e4, 2.5e6], np.float32)[:, None]
    for bits in (4, 8):
        codes, scales = quant.quantize_rows(x, bits)
        back = quant.dequantize_rows(codes, scales, bits, 12)
        np.testing.assert_allclose(
            back.max(axis=-1), x.max(axis=-1), rtol=1e-6)


@given(k=st.integers(min_value=1, max_value=40))
@settings(max_examples=60, deadline=None)
def test_nibble_packing_roundtrip_odd_lengths(k):
    rng = np.random.default_rng(k)
    codes = rng.integers(0, 16, (3, k)).astype(np.uint8)
    packed = quant.pack_nibbles(codes)
    assert packed.shape[-1] == (k + 1) // 2
    assert np.array_equal(quant.unpack_nibbles(packed, k), codes)


def test_jnp_twins_match_numpy():
    rng = np.random.default_rng(9)
    x = (rng.random((6, 11)) * 40).astype(np.float32)
    for bits in (4, 8):
        codes_np, scales_np = quant.quantize_rows(x, bits)
        codes_j, scales_j = quant.quantize_rows_jnp(jnp.asarray(x), bits)
        np.testing.assert_allclose(np.asarray(scales_j), scales_np,
                                   rtol=1e-6)
        packed_j = (quant.pack_nibbles_jnp(codes_j) if bits == 4
                    else codes_j)
        assert np.array_equal(np.asarray(packed_j), codes_np)
        unpacked = quant.unpack_nibbles_jnp(jnp.asarray(codes_np), 11)
        if bits == 4:
            assert np.array_equal(np.asarray(unpacked),
                                  quant.unpack_nibbles(codes_np, 11))
    fq = quant.fake_quantize_rows(x, 8)
    fq_j = np.asarray(quant.fake_quantize_rows(jnp.asarray(x), 8))
    np.testing.assert_allclose(fq_j, fq, rtol=1e-5, atol=1e-5)


# -- fixed mode bit-exactness -------------------------------------------------


def test_fixed_mode_is_bit_exact_vs_legacy_w_bits():
    cfg_old, corpus = _corpus(w_bits=8)
    cfg_new = LDAConfig(num_topics=cfg_old.num_topics,
                        vocab_size=cfg_old.vocab_size,
                        num_docs=cfg_old.num_docs, w_bits=8,
                        quant=QuantSpec.fixed(8))
    out_old = gibbs.run(cfg_old, corpus, jax.random.PRNGKey(0),
                        num_sweeps=3)
    out_new = gibbs.run(cfg_new, corpus, jax.random.PRNGKey(0),
                        num_sweeps=3)
    assert np.array_equal(np.asarray(out_old.z), np.asarray(out_new.z))
    assert np.array_equal(np.asarray(out_old.n_wt), np.asarray(out_new.n_wt))


# -- wire array codec ---------------------------------------------------------


def test_raw_wire_form_unchanged_without_spec():
    # A pre-quant decoder must keep parsing what we emit by default.
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    d = protocol.encode_array(x)
    assert set(d) == {"dtype", "shape", "b64"}
    assert "enc" not in d
    assert np.array_equal(protocol.decode_array(d), x)


@pytest.mark.parametrize("mode", ["int8", "int4_packed"])
def test_quantized_wire_roundtrip(mode):
    spec = QuantSpec.from_wire(mode)
    rng = np.random.default_rng(5)
    x = (rng.random((20, 16)) * 100).astype(np.float32)
    d = protocol.encode_array(x, spec=spec)
    assert d["enc"] == "q" and d["mode"] == mode
    back = protocol.decode_array(d)
    assert back.dtype == x.dtype and back.shape == x.shape
    _, scales = quant.quantize_rows(x, spec.bits)
    assert np.all(np.abs(back - x) <= scales[:, None] / 2 + 1e-4)
    # int dtypes round back to integers on dequant.
    xi = (x * 4).astype(np.int32)
    di = protocol.encode_array(xi, spec=spec)
    bi = protocol.decode_array(di)
    assert bi.dtype == np.int32


def test_quantized_wire_is_smaller():
    x = np.random.default_rng(0).random((64, 32)).astype(np.float32) * 50
    raw = len(protocol.encode_array(x)["b64"])
    q8 = len(protocol.encode_array(x, spec=QuantSpec.int8())["b64"])
    q4 = len(protocol.encode_array(x, spec=QuantSpec.int4())["b64"])
    assert q8 < raw / 3  # ~4x minus scale overhead (scales ride separately)
    assert q4 < q8


def test_state_arrays_pack_only_count_tables():
    cfg, corpus = _corpus(w_bits=None)
    state = init_state(cfg, corpus, jax.random.PRNGKey(0))
    d = protocol.encode_state_arrays(state, spec=QuantSpec.int8())
    assert d["z"].get("enc") is None  # ground truth ships raw
    assert d["n_t"].get("enc") is None
    assert d["n_dt"]["enc"] == "q" and d["n_wt"]["enc"] == "q"
    assert protocol.state_arrays_quantized(d)
    assert not protocol.state_arrays_quantized(
        protocol.encode_state_arrays(state))
    arrays = protocol.decode_state_arrays(d)
    assert np.array_equal(arrays["z"], np.asarray(state.z))


def test_api_codec_is_the_documented_home():
    # Both codecs import from one surface, under distinct names.
    assert api_codec.codec_for is codec.codec_for
    assert api_codec.QuantSpec is QuantSpec
    assert api_codec.encode_wire_array is protocol.encode_array
    assert api_codec.decode_wire_array is protocol.decode_array
    assert api_codec.QUANT_STATE_FIELDS == ("n_dt", "n_wt")


# -- view versioning ----------------------------------------------------------


def _view():
    return ModelView(topics=[
        TopicView(topic_id=3, probability=0.25, expected_rating=4.1,
                  expected_helpful=0.6, expected_unhelpful=0.1,
                  top_words=[5, 9, 2], top_word_weights=[7.0, 3.5, 1.25]),
        TopicView(topic_id=1, probability=0.75, expected_rating=2.0,
                  expected_helpful=0.0, expected_unhelpful=0.0,
                  top_words=[4], top_word_weights=[0.0]),
    ])


def test_view_v1_serialization_is_plain_list():
    import json

    v = _view()
    s = v.to_json()
    assert isinstance(json.loads(s), list)  # pre-quant contract holds
    back = ModelView.from_json(s)
    assert back.topics[0].to_dict() == v.topics[0].to_dict()


@pytest.mark.parametrize("mode", ["int8", "int4_packed"])
def test_view_v2_quantized_roundtrip(mode):
    import json

    v = _view()
    spec = QuantSpec.from_wire(mode)
    s = v.to_json(quant_spec=spec)
    obj = json.loads(s)
    assert obj["view_version"] == VIEW_VERSION and obj["quant"] == mode
    back = ModelView.from_json(s)
    for t_in, t_out in zip(v.topics, back.topics):
        assert t_out.topic_id == t_in.topic_id
        assert t_out.top_words == t_in.top_words
        w_in = np.asarray(t_in.top_word_weights)
        w_out = np.asarray(t_out.top_word_weights)
        step = w_in.max() / (2 ** spec.bits - 1) if w_in.max() else 0.0
        assert np.all(np.abs(w_out - w_in) <= step / 2 + 1e-6)
    assert len(s) < len(v.to_json())  # compact form is actually smaller


def test_future_view_version_raises_typed_resync():
    import json

    s = json.dumps({"view_version": VIEW_VERSION + 1, "topics": []})
    with pytest.raises(ViewVersionError) as ei:
        ModelView.from_json(s)
    assert ei.value.resync is True
    assert ei.value.got == VIEW_VERSION + 1
    assert isinstance(ei.value, ValueError)  # old catch-sites still catch


# -- end-to-end through the client -------------------------------------------


@pytest.fixture(scope="module")
def fitted():
    client = VedaliaClient(backend="jnp", num_sweeps=6, update_sweeps=1)
    fit = client.fit(_reviews(), num_topics=8, base_vocab=120, w_bits=8,
                     seed=0)
    return client, fit.handle_id


def test_hello_advertises_quant(fitted):
    client, _ = fitted
    hello = client._call("hello", {})
    assert list(quant.PACKED_MODES) == hello["quant_modes"]
    assert hello["view_version"] == VIEW_VERSION


def test_quantized_view_matches_unquantized_topics(fitted):
    client, hid = fitted
    plain = client.view(hid, top_n=8)
    q = client.view(hid, top_n=8, quant="int8")
    assert q.payload_bytes < plain.payload_bytes
    assert [t.topic_id for t in q.topics] == [
        t.topic_id for t in plain.topics]
    for tp, tq in zip(plain.topics, q.topics):
        assert tp.top_words == tq.top_words
        w = np.asarray(tp.top_word_weights)
        step = (w.max() / 255) if w.size and w.max() else 0.0
        assert np.all(np.abs(np.asarray(tq.top_word_weights) - w)
                      <= step / 2 + 1e-6)


def test_quantized_delta_view_same_topic_set(fitted):
    client, hid = fitted
    full = client.sync_view(hid, top_n=8)
    client.update(hid, _reviews(n=8, seed=91), seed=3)
    delta = client.view(hid, since=full.cursor, top_n=8)
    delta_q = client.view(hid, since=full.cursor, top_n=8, quant="int8")
    # Cursor signatures come from the unquantized view on both syncs, so
    # the re-sent topic set is identical; only the encoding shrinks.
    assert ([t.topic_id for t in delta_q.topics]
            == [t.topic_id for t in delta.topics])
    if delta.topics:
        assert delta_q.payload_bytes < delta.payload_bytes


@pytest.mark.parametrize("mode", ["int8", "int4_packed"])
def test_quantized_export_rebuilds_exact_state(fitted, mode):
    client, hid = fitted
    exact = client.export_model(hid)
    packed = client.export_model(hid, quant=mode)
    assert np.array_equal(np.asarray(packed.state.z),
                          np.asarray(exact.state.z))
    # Counts rebuilt from raw z are bit-exact despite the lossy download.
    assert np.array_equal(np.asarray(packed.state.n_wt),
                          np.asarray(exact.state.n_wt))
    assert np.array_equal(np.asarray(packed.state.n_dt),
                          np.asarray(exact.state.n_dt))


def test_quantized_spot_check_and_adopt(fitted):
    client, hid = fitted
    exp = client.export_model(hid)
    res = client.spot_check(hid, exp.state, num_sweeps=1, seed=5,
                            quant="int8")
    assert res.valid, res.reason
    adopted = client.adopt_state(hid, exp.state, sweeps_run=exp.sweeps_run,
                                 quant="int8")
    assert adopted.handle_id == hid


def test_quantized_upload_of_phony_claim_still_fails(fitted):
    client, hid = fitted
    exp = client.export_model(hid)
    # Quantized uploads rebuild counts from z, so count *fabrication* is
    # erased by construction — the surviving attack is a phony quality
    # claim on a degenerate state, and the claim check must still catch
    # it after the rebuild.
    bad_z = jnp.zeros_like(exp.state.z)
    bad = type(exp.state)(z=bad_z, n_dt=exp.state.n_dt,
                          n_wt=exp.state.n_wt, n_t=exp.state.n_t)
    res = client.spot_check(hid, bad, claimed_perplexity=1.0,
                            num_sweeps=1, seed=5, quant="int8")
    assert not res.valid


def test_raw_upload_of_inconsistent_counts_still_fails(fitted):
    client, hid = fitted
    exp = client.export_model(hid)
    # Unquantized uploads keep the original defense: counts that disagree
    # with their own assignments fail structural validation unchanged.
    bad = type(exp.state)(z=exp.state.z, n_dt=exp.state.n_dt,
                          n_wt=exp.state.n_wt * 3, n_t=exp.state.n_t)
    res = client.spot_check(hid, bad, num_sweeps=0, seed=5)
    assert not res.valid


# -- packed kernel paths ------------------------------------------------------


@pytest.mark.parametrize("spec", [QuantSpec.int8(w_bits=8),
                                  QuantSpec.int4(w_bits=8)])
def test_packed_gibbs_kernel_sweep_runs(spec):
    from repro.kernels.lda_gibbs import ops

    cfg_ref, corpus = _corpus(n=1500, w_bits=8)
    cfg_q = LDAConfig(num_topics=cfg_ref.num_topics,
                      vocab_size=cfg_ref.vocab_size,
                      num_docs=cfg_ref.num_docs, w_bits=8, quant=spec)
    state = codec.encode_state(
        cfg_ref, init_state(cfg_ref, corpus, jax.random.PRNGKey(1)))
    z_ref = ops.sweep_resample(cfg_ref, state, corpus,
                               jax.random.PRNGKey(2))
    z_q = ops.sweep_resample(cfg_q, state, corpus, jax.random.PRNGKey(2))
    assert z_q.shape == z_ref.shape
    assert int(jnp.min(z_q)) >= 0
    assert int(jnp.max(z_q)) < cfg_q.num_topics
    # The packed table is a scale/2-perturbed score surface; most tokens
    # must still land where the exact sweep lands them.
    agree = float(jnp.mean((z_q == z_ref).astype(jnp.float32)))
    assert agree > 0.8, f"packed sweep diverged: agreement {agree:.2%}"


@pytest.mark.parametrize("spec", [QuantSpec.int8(w_bits=8),
                                  QuantSpec.int4(w_bits=8)])
def test_packed_alias_kernel_sweep_runs(spec):
    from repro.kernels.alias_mh import ops

    cfg_ref, corpus = _corpus(n=1500, w_bits=8)
    cfg_q = LDAConfig(num_topics=cfg_ref.num_topics,
                      vocab_size=cfg_ref.vocab_size,
                      num_docs=cfg_ref.num_docs, w_bits=8, quant=spec)
    state = codec.encode_state(
        cfg_ref, init_state(cfg_ref, corpus, jax.random.PRNGKey(1)))
    z_ref = ops.mh_resample(cfg_ref, state, corpus, jax.random.PRNGKey(2))
    z_q = ops.mh_resample(cfg_q, state, corpus, jax.random.PRNGKey(2))
    assert z_q.shape == z_ref.shape
    assert int(jnp.min(z_q)) >= 0
    assert int(jnp.max(z_q)) < cfg_q.num_topics
    agree = float(jnp.mean((z_q == z_ref).astype(jnp.float32)))
    assert agree > 0.8, f"packed MH sweep diverged: agreement {agree:.2%}"
