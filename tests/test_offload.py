"""Chital offload tier: state-carrying wire verbs, the simulated device
fleet, and the coordinator's lease → validate → verify → adopt loop."""

import dataclasses

import numpy as np
import pytest

from repro.api import VedaliaClient, VedaliaServer, protocol
from repro.api.backends import get_backend
from repro.core import perplexity as perplexity_lib
from repro.data import reviews as reviews_data
from repro.offload import (
    CORRUPT,
    FABRICATE,
    FABRICATE_CLAIM_RATIO,
    HONEST,
    DeviceFleet,
    FleetSpec,
    OffloadCoordinator,
    OffloadTask,
)
from repro.stream import (
    IncrementalScheduler,
    StreamRouter,
    StreamSpec,
    pump,
    synthetic_events,
)


def _reviews(n=20, vocab=120, seed=0):
    return reviews_data.generate(reviews_data.SyntheticSpec(
        num_reviews=n, vocab_size=vocab, num_topics=4, mean_tokens=25,
        seed=seed)).reviews


def _client(**kw):
    return VedaliaClient(backend="jnp", num_sweeps=4, update_sweeps=1, **kw)


def _fit(client, n=20, seed=0):
    return client.fit(_reviews(n=n, seed=seed), num_topics=4,
                      base_vocab=120)


# -- state codec --------------------------------------------------------------


def test_state_arrays_roundtrip_and_missing_field():
    client = _client()
    fit = _fit(client)
    exported = client.export_model(fit.handle_id)
    enc = protocol.encode_state_arrays(exported.state)
    assert set(enc) == set(protocol.STATE_FIELDS)
    dec = protocol.decode_state_arrays(enc)
    for name in protocol.STATE_FIELDS:
        np.testing.assert_array_equal(
            dec[name], np.asarray(getattr(exported.state, name)))
    enc.pop("n_wt")
    with pytest.raises(protocol.ProtocolError, match="missing field"):
        protocol.decode_state_arrays(enc)
    with pytest.raises(protocol.ProtocolError, match="JSON object"):
        protocol.decode_state_arrays([1, 2, 3])


# -- export / spot_check / adopt_state ---------------------------------------


def test_export_model_roundtrip():
    client = _client()
    fit = _fit(client)
    exported = client.export_model(fit.handle_id)
    assert exported.handle_id == fit.handle_id
    assert exported.cfg.num_topics == 4
    assert exported.num_tokens == exported.corpus.num_tokens
    assert exported.state.z.shape == (exported.corpus.num_tokens,)
    # The exported state really is the served state: same perplexity.
    ppx = float(perplexity_lib.perplexity(
        exported.cfg, exported.state, exported.corpus))
    assert ppx == pytest.approx(client.perplexity(fit.handle_id), rel=1e-6)


def test_spot_check_accepts_honest_continuation():
    client = _client()
    fit = _fit(client)
    exported = client.export_model(fit.handle_id)
    # A real device-side continuation of the chain.
    import jax
    state = get_backend("jnp").run(
        exported.cfg, exported.corpus, jax.random.PRNGKey(7), 3,
        state=exported.state)
    claimed = float(perplexity_lib.perplexity(
        exported.cfg, state, exported.corpus))
    check = client.spot_check(fit.handle_id, state,
                              claimed_perplexity=claimed)
    assert check.valid, check.reason
    assert check.state_perplexity == pytest.approx(claimed, rel=1e-6)
    assert check.post_perplexity is None  # no re-Gibbs requested


def test_spot_check_catches_fabricated_claim():
    client = _client()
    fit = _fit(client)
    exported = client.export_model(fit.handle_id)
    true_ppx = float(perplexity_lib.perplexity(
        exported.cfg, exported.state, exported.corpus))
    check = client.spot_check(fit.handle_id, exported.state,
                              claimed_perplexity=0.55 * true_ppx)
    assert not check.valid
    assert "claim" in check.reason


def test_spot_check_catches_corrupted_state():
    client = _client()
    fit = _fit(client)
    exported = client.export_model(fit.handle_id)
    perm = np.random.default_rng(0).permutation(
        int(exported.state.n_wt.shape[0]))
    tampered = dataclasses.replace(
        exported.state, n_wt=np.asarray(exported.state.n_wt)[perm])
    check = client.spot_check(fit.handle_id, tampered)
    assert not check.valid  # counts disagree with the assignments


def test_spot_check_regibbs_leaves_handle_untouched():
    client = _client()
    fit = _fit(client)
    exported = client.export_model(fit.handle_id)
    before = client.perplexity(fit.handle_id)
    check = client.spot_check(fit.handle_id, exported.state, num_sweeps=2,
                              seed=3)
    assert check.valid
    assert check.post_perplexity is not None
    assert np.isfinite(check.post_perplexity)
    # The re-Gibbs ran on a throwaway copy: the served model is unchanged.
    assert client.perplexity(fit.handle_id) == pytest.approx(before)


def test_adopt_state_swaps_serving_state_and_validates():
    client = _client()
    fit = _fit(client)
    exported = client.export_model(fit.handle_id)
    import jax
    state = get_backend("jnp").run(
        exported.cfg, exported.corpus, jax.random.PRNGKey(11), 3,
        state=exported.state)
    device_ppx = float(perplexity_lib.perplexity(
        exported.cfg, state, exported.corpus))
    res = client.adopt_state(fit.handle_id, state, sweeps_run=3)
    assert res.handle_id == fit.handle_id
    assert client.perplexity(fit.handle_id) == pytest.approx(
        device_ppx, rel=1e-6)
    # The handle keeps serving views after adoption.
    assert client.sync_view(fit.handle_id).valid

    # A tampered state is refused at the trust boundary.
    perm = np.random.default_rng(0).permutation(int(state.n_wt.shape[0]))
    tampered = dataclasses.replace(state, n_wt=np.asarray(state.n_wt)[perm])
    with pytest.raises(protocol.RemoteError, match="refusing to adopt"):
        client.adopt_state(fit.handle_id, tampered)
    assert client.perplexity(fit.handle_id) == pytest.approx(
        device_ppx, rel=1e-6)  # refusal left the model alone


# -- fleet --------------------------------------------------------------------


def test_fleet_population_is_deterministic():
    spec = FleetSpec(num_devices=20, malicious_frac=0.2, fabricate_frac=0.5,
                     straggler_frac=0.1, seed=3)
    a, b = DeviceFleet(spec), DeviceFleet(spec)
    assert {i: d.behavior for i, d in a.devices.items()} \
        == {i: d.behavior for i, d in b.devices.items()}
    assert [d.speed for d in a.devices.values()] \
        == [d.speed for d in b.devices.values()]
    behaviors = [d.behavior for d in a.devices.values()]
    assert behaviors.count(FABRICATE) == 2
    assert behaviors.count(CORRUPT) == 2
    assert behaviors.count(HONEST) == 16
    assert sum(d.straggler_factor > 1.0 for d in a.devices.values()) == 2
    sellers = a.sellers()
    assert len(sellers) == 20
    assert all(s.honest == a.devices[s.seller_id].honest for s in sellers)


def _task(fit, tokens, num_sweeps=2, task_id=0):
    return OffloadTask(task_id=task_id, shard_id=0, handle_id=fit.handle_id,
                       product_id=0, tokens=tokens, num_sweeps=num_sweeps)


def test_honest_device_runs_a_real_fit():
    client = _client()
    fit = _fit(client)
    fleet = DeviceFleet(FleetSpec(num_devices=4, malicious_frac=0.0,
                                  churn_prob=0.0, straggler_frac=0.0,
                                  backend="jnp", seed=0))
    exported = client.export_model(fit.handle_id)
    task = _task(fit, tokens=exported.num_tokens)
    run = fleet.execute(0, task, client.transport)
    assert run.completed and not run.churned and not run.timed_out
    sub = run.submission
    assert sub.valid and sub.payload is not None
    assert sub.iterations == task.num_sweeps
    # The claimed perplexity is the *real* perplexity of the uploaded
    # state — the server's recompute agrees exactly.
    check = client.spot_check(fit.handle_id, sub.payload,
                              claimed_perplexity=sub.perplexity)
    assert check.valid, check.reason
    # And the chain actually moved: the assignments changed.
    assert not np.array_equal(np.asarray(sub.payload.z),
                              np.asarray(exported.state.z))
    # Replayable: same (seed, device, task) -> identical submission.
    rerun = fleet.execute(0, task, client.transport)
    assert rerun.submission.perplexity == sub.perplexity
    np.testing.assert_array_equal(np.asarray(rerun.submission.payload.z),
                                  np.asarray(sub.payload.z))


def test_malicious_devices_are_caught_by_spot_check():
    client = _client()
    fit = _fit(client)
    spec = FleetSpec(num_devices=2, malicious_frac=1.0, fabricate_frac=0.5,
                     churn_prob=0.0, straggler_frac=0.0, backend="jnp",
                     seed=0)
    fleet = DeviceFleet(spec)
    by_behavior = {d.behavior: d.device_id for d in fleet.devices.values()}
    assert set(by_behavior) == {FABRICATE, CORRUPT}
    exported = client.export_model(fit.handle_id)

    fab = fleet.execute(by_behavior[FABRICATE],
                        _task(fit, exported.num_tokens), client.transport)
    true_ppx = float(perplexity_lib.perplexity(
        exported.cfg, exported.state, exported.corpus))
    assert fab.submission.perplexity == pytest.approx(
        FABRICATE_CLAIM_RATIO * true_ppx)
    check = client.spot_check(fit.handle_id, fab.submission.payload,
                              claimed_perplexity=fab.submission.perplexity)
    assert not check.valid  # implausibly good claim vs the recompute

    cor = fleet.execute(by_behavior[CORRUPT],
                        _task(fit, exported.num_tokens), client.transport)
    check = client.spot_check(fit.handle_id, cor.submission.payload,
                              claimed_perplexity=cor.submission.perplexity)
    assert not check.valid  # tampered counts fail the rebuild check


def test_churn_and_straggler_deadline():
    client = _client()
    fit = _fit(client)
    fleet = DeviceFleet(FleetSpec(num_devices=1, malicious_frac=0.0,
                                  churn_prob=1.0, backend="jnp", seed=0))
    run = fleet.execute(0, _task(fit, 100), client.transport)
    assert run.churned and not run.completed
    assert not run.submission.valid and run.submission.payload is None

    slow = DeviceFleet(FleetSpec(num_devices=1, malicious_frac=0.0,
                                 churn_prob=0.0, straggler_frac=1.0,
                                 straggler_factor=8.0, backend="jnp",
                                 seed=0))
    # Deadline sized for the advertised speed: the straggler (8x slower
    # than advertised) misses it and the lease expires without an upload.
    task = _task(fit, 100)
    deadline = 2.0 * (task.tokens * task.num_sweeps) / slow.min_speed
    run = slow.execute(0, task, client.transport, deadline=deadline)
    assert run.timed_out and not run.completed
    # No deadline -> the slow device eventually finishes a real fit.
    run = slow.execute(0, task, client.transport)
    assert run.completed and run.submission.valid


# -- coordinator --------------------------------------------------------------


@pytest.fixture(scope="module")
def offload_run():
    """One short adversarial stream driven through the offload tier."""
    spec = StreamSpec(num_products=3, duration=30.0, rate=2.0,
                      shape="burst", shift_at=15.0, seed=0)
    events = synthetic_events(spec)
    router = StreamRouter([0, 1], capacity=64)
    servers = {s: VedaliaServer(backend="jnp", num_sweeps=4,
                                update_sweeps=1) for s in (0, 1)}
    clients = {s: VedaliaClient(server=servers[s]) for s in (0, 1)}
    fleet = DeviceFleet(FleetSpec(num_devices=12, malicious_frac=0.25,
                                  churn_prob=0.1, straggler_frac=0.15,
                                  backend="jnp", seed=0))
    coord = OffloadCoordinator(fleet, seed=0)
    sched = IncrementalScheduler(
        clients, router, microbatch=6, min_fit_reviews=8,
        staleness_budget=8.0, refit_sweeps=3, refit_policy="always",
        refit_executor=coord,
        fit_kwargs=dict(num_topics=4, base_vocab=spec.vocab_size,
                        num_sweeps=4))
    pump(events, router, sched, step_interval=2.0)
    return clients, fleet, coord, sched


def test_coordinator_leases_every_refit(offload_run):
    _, _, coord, sched = offload_run
    st = coord.stats
    assert sched.stats.refits > 0
    assert st.tasks == sched.stats.refits
    # The executor owns the launches 1:1 and the built-in server refit
    # path never ran.
    assert sched.stats.refit_launches == st.tasks
    assert sched.stats.refit_sweep_work == 0.0
    # Every task resolved: adopted from a device or explicitly fell back.
    assert st.adopted + st.fallbacks == st.tasks
    assert st.adopted > 0  # the fleet actually took work
    assert st.device_sweep_work > 0


def test_coordinator_never_adopts_phony(offload_run):
    _, fleet, coord, _ = offload_run
    assert coord.stats.adopted_phony == 0
    # Validation did real work: the adversarial fleet produced invalid
    # submissions and they were all caught before selection.
    assert coord.stats.invalid_submissions > 0


def test_coordinator_keeps_views_serving(offload_run):
    clients, _, coord, sched = offload_run
    for status in sched.products.values():
        client = clients[status.shard_id]
        assert client.sync_view(status.handle_id).valid
        ppx = client.perplexity(status.handle_id)
        assert np.isfinite(ppx) and ppx > 0


def test_coordinator_credit_separates_honest_from_malicious(offload_run):
    _, fleet, coord, _ = offload_run
    ledger = coord.marketplace.ledger
    honest = [ledger.get(d.device_id) for d in fleet.devices.values()
              if d.honest]
    malicious = [ledger.get(d.device_id) for d in fleet.devices.values()
                 if not d.honest]
    assert np.mean(honest) > np.mean(malicious)
    assert abs(ledger.total()) < 1e-9  # zero-sum survived the whole run


def test_coordinator_falls_back_when_fleet_is_empty():
    """Zero devices: every lease is an unmatched fallback — the server
    refits itself and serving never stalls."""
    spec = StreamSpec(num_products=1, duration=15.0, rate=2.0,
                      shape="burst", shift_at=None, seed=0)
    events = synthetic_events(spec)
    router = StreamRouter([0], capacity=64)
    client = _client()
    coord = OffloadCoordinator(
        DeviceFleet(FleetSpec(num_devices=0)), seed=0)
    sched = IncrementalScheduler(
        {0: client}, router, microbatch=5, min_fit_reviews=6,
        staleness_budget=6.0, refit_sweeps=2, refit_policy="always",
        refit_executor=coord,
        fit_kwargs=dict(num_topics=4, base_vocab=spec.vocab_size,
                        num_sweeps=3))
    pump(events, router, sched, step_interval=2.0)
    st = coord.stats
    assert st.tasks > 0
    assert st.fallback_unmatched == st.tasks and st.adopted == 0
    # The fallback really refined: full server sweep-work was charged.
    assert st.server_sweep_work > 0
    assert coord.marketplace.matched_rate() == 0.0
    for status in sched.products.values():
        assert client.sync_view(status.handle_id).valid
