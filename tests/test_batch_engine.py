"""Batched multi-model fit engine: parity, bucketing, protocol, coalescing.

The PR-4 acceptance gates:
  * a batched `sweep_batch` is bit-exact M independent single-model sweeps
    (same keys -> same chains) on the jnp oracle path;
  * `fit_batch` over M toy corpora matches per-model sequential fits —
    perplexity within tolerance, exact per-model count invariants;
  * the `auto` selector routes multi-model work to `batched`;
  * `fit_batch`/`refine_batch` protocol verbs round-trip with per-model
    results in request order;
  * `stream.IncrementalScheduler` coalesces same-window refits into one
    `refine_batch` launch per shard.
"""

import jax
import numpy as np
import pytest

from repro.api import VedaliaClient, select_backend
from repro.api.backends import backend_capabilities, get_backend
from repro.api.protocol import RemoteError
from repro.api.service import FitRequest, VedaliaService
from repro.core import batch as batch_lib
from repro.core import codec, gibbs, rlda
from repro.core.types import LDAConfig
from repro.data import reviews as reviews_data
from repro.serving import batch_engine
from repro.serving.topic_engine import TopicEngine
from repro.stream import IncrementalScheduler, ReviewEvent, StreamRouter


def _review_sets(m, n=14, vocab=200, topics=4):
    sets = []
    for s in range(m):
        spec = reviews_data.SyntheticSpec(
            num_reviews=n, vocab_size=vocab, num_topics=topics,
            mean_tokens=25, num_users=30, seed=50 + s)
        sets.append(reviews_data.generate(spec).reviews)
    return sets


def _preps(m, **kw):
    return [
        rlda.prepare(rs, base_vocab=200, num_topics=6, **kw)
        for rs in _review_sets(m)
    ]


def _assert_count_invariants(cfg, corpus, state):
    """Exact per-model invariants, to fixed-point codec resolution."""
    n_dt, n_wt, n_t = codec.decode_counts_np(cfg, state)
    w = np.asarray(corpus.weights, np.float64)
    docs = np.asarray(corpus.docs)
    # one ulp of the stored representation per contributing array entry
    eps = (0.5 / 2 ** (cfg.w_bits + 1)) if cfg.w_bits is not None else 1e-4
    per_doc = np.zeros(cfg.num_docs)
    np.add.at(per_doc, docs, w)
    np.testing.assert_allclose(
        n_dt.sum(axis=1), per_doc, atol=eps * cfg.num_topics + 1e-3)
    np.testing.assert_allclose(
        n_wt.sum(axis=0), n_t, atol=eps * (cfg.vocab_size + 1) + 1e-3)
    assert abs(n_wt.sum() - w.sum()) <= eps * corpus.num_tokens + 1e-2


# -- registry / selector -----------------------------------------------------


def test_batched_backend_registered_with_capabilities():
    caps = backend_capabilities("batched")
    assert caps.warm_start and caps.weighted
    assert caps.device_kind == "tpu"


def test_auto_selector_routes_multi_model_to_batched():
    assert select_backend(num_models=4) == "batched"
    assert select_backend(num_models=16, task="update") == "batched"
    assert select_backend(num_models=1) == "jnp"
    # device_kind still wins: a phone stack is not a batched TPU fit
    assert select_backend(num_models=4, device_kind="phone") == "sparse"
    # degraded registries fall back
    assert select_backend(num_models=4, available=["jnp"]) == "jnp"


def test_unknown_batched_path_rejected():
    with pytest.raises(ValueError, match="path"):
        get_backend("batched", path="cuda")


# -- bucketing ---------------------------------------------------------------


def test_length_and_doc_buckets_are_power_of_two_ladders():
    assert batch_engine.length_bucket(1) == 256
    assert batch_engine.length_bucket(256) == 256
    assert batch_engine.length_bucket(257) == 512
    assert batch_engine.length_bucket(900) == 1024
    assert batch_engine.doc_bucket(17) == 32


def test_plan_buckets_groups_compatible_models():
    preps = _preps(3, w_bits=8)
    other = rlda.prepare(_review_sets(1)[0], base_vocab=200, num_topics=9,
                         w_bits=8)  # different K: never stacks
    items = [(p.cfg, p.corpus) for p in preps] + [(other.cfg, other.corpus)]
    buckets = batch_engine.plan_buckets(items)
    by_len = {tuple(sorted(b)) for b in buckets}
    assert all(3 not in b or len(b) == 1 for b in by_len)  # K=9 isolated
    # max_models splits a bucket
    split = batch_engine.plan_buckets(items[:3], max_models=2)
    assert sorted(len(b) for b in split) in ([1, 2], [1, 1, 1])
    assert sorted(i for b in split for i in b) == [0, 1, 2]


def test_batch_cfg_rejects_incompatible_models():
    a = LDAConfig(num_topics=4, vocab_size=100, num_docs=8)
    b = LDAConfig(num_topics=8, vocab_size=100, num_docs=8)
    with pytest.raises(ValueError, match="incompatible"):
        batch_lib.batch_cfg([a, b], 8)
    with pytest.raises(ValueError, match="capacity"):
        batch_lib.batch_cfg([a], 4)


# -- sweep parity ------------------------------------------------------------


@pytest.mark.parametrize("w_bits", [None, 8])
def test_sweep_batch_is_exactly_m_single_model_sweeps(w_bits):
    preps = _preps(3, w_bits=w_bits)
    cfgs = [p.cfg for p in preps]
    n_pad = max(p.corpus.num_tokens for p in preps)
    bcfg = batch_lib.batch_cfg(
        cfgs, batch_engine.doc_bucket(max(c.num_docs for c in cfgs)))
    stacked = batch_lib.stack_corpora([p.corpus for p in preps], n_pad)
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    states = batch_lib.init_many(bcfg, stacked, keys)

    out = batch_lib.sweep_batch(bcfg, states, stacked, keys)
    for i, p in enumerate(preps):
        n = p.corpus.num_tokens
        padded = batch_lib.pad_corpus(p.corpus, n_pad)
        st_i = codec.rebuild_state(bcfg, padded, states.z[i])
        ref = gibbs.sweep(bcfg, st_i, padded, keys[i])
        np.testing.assert_array_equal(
            np.asarray(out.z[i, :n]), np.asarray(ref.z[:n]))


def test_unstack_states_trims_and_rebuilds_per_model():
    preps = _preps(2, w_bits=8)
    cfgs = [p.cfg for p in preps]
    corpora = [p.corpus for p in preps]
    keys = [jax.random.PRNGKey(i) for i in range(2)]
    states, stats = batch_engine.run_batched(
        get_backend("batched", path="jnp"), cfgs, corpora, keys, 3)
    assert stats.num_models == 2 and stats.num_launches >= 1
    for cfg, corpus, st in zip(cfgs, corpora, states):
        assert st.z.shape == (corpus.num_tokens,)
        assert st.n_dt.shape == (cfg.num_docs, cfg.num_topics)
        _assert_count_invariants(cfg, corpus, st)


# -- service-level parity (the PR acceptance test) ---------------------------


def test_fit_batch_matches_sequential_fits():
    """fit_many over M=4 toy corpora vs per-model sequential fits: same
    seeds -> perplexity within tolerance, exact count invariants."""
    m, sweeps = 4, 12
    sets = _review_sets(m)

    seq_svc = VedaliaService(backend="jnp", num_sweeps=sweeps)
    seq_ppx = []
    for i, rs in enumerate(sets):
        h = seq_svc.fit(rs, num_topics=6, base_vocab=200, seed=7 + i)
        seq_ppx.append(seq_svc.perplexity(h))

    bat_svc = VedaliaService(backend="auto", num_sweeps=sweeps)
    handles = bat_svc.fit_batch(sets, num_topics=6, base_vocab=200, seed=7)
    assert [h.backend for h in handles] == ["batched"] * m
    assert sorted(bat_svc.handles) == [h.handle_id for h in handles]

    for h, ps in zip(handles, seq_ppx):
        pb = bat_svc.perplexity(h)
        # Different chains (independent keys): converged-quality parity,
        # same tolerance as the kernel-vs-oracle statistics test.
        assert abs(np.log(pb) - np.log(ps)) < 0.3, (pb, ps)
        _assert_count_invariants(h.cfg, h.model.corpus, h.state)


def test_fit_batch_single_model_falls_back_to_sequential():
    svc = VedaliaService(backend="auto", num_sweeps=4)
    (h,) = svc.fit_batch(_review_sets(1), num_topics=4, base_vocab=200)
    assert h.backend == "jnp"  # num_models=1 never routes to batched


def test_fit_batch_rejects_empty_sets():
    svc = VedaliaService(num_sweeps=2)
    with pytest.raises(ValueError, match="at least one"):
        svc.fit_batch([])
    with pytest.raises(ValueError, match="set 1 is empty"):
        svc.fit_batch([_review_sets(1)[0], []])


def test_refine_many_dedups_repeated_handles():
    svc = VedaliaService(backend="auto", num_sweeps=4)
    handles = svc.fit_batch(_review_sets(2), num_topics=4, base_vocab=200)
    h = handles[0]
    before = h.sweeps_run
    out = svc.refine_many([h, h, handles[1]], 3)
    assert len(out) == 3  # input order/length preserved
    assert h.sweeps_run == before + 3  # one model, one refit


def test_refine_many_sequential_fallback_derives_per_handle_seeds():
    svc = VedaliaService(backend="jnp", num_sweeps=4)
    handles = svc.fit_batch(_review_sets(2), num_topics=4, base_vocab=200,
                            backend="jnp")
    svc.refine_many(handles, 3, backend="jnp", seed=11)
    # identical seeds would give both models the same gumbel stream; with
    # per-handle derivation the refined states must differ
    assert not np.array_equal(np.asarray(handles[0].state.z[:50]),
                              np.asarray(handles[1].state.z[:50]))
    assert all(h.backend == "jnp" for h in handles)


def test_perf_gate_update_refuses_partial_summary(tmp_path):
    import importlib.util
    import json
    import os

    spec = importlib.util.spec_from_file_location(
        "perf_gate", os.path.join(
            os.path.dirname(__file__), "..", "benchmarks", "perf_gate.py"))
    perf_gate = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(perf_gate)

    summary = tmp_path / "summary.json"
    baseline = tmp_path / "baseline.json"
    summary.write_text(json.dumps({
        "benches": {"sampler": {"samplers": {
            "parallel": {"tokens_per_s": 100},
            "kernel": {"tokens_per_s": 100}}}}}))
    # partial (no `batch` bench): --update must refuse, not drop the gate
    assert perf_gate.main(["--summary", str(summary),
                           "--baseline", str(baseline), "--update"]) == 1
    assert not baseline.exists()
    # a full summary (every gated bench) refreshes, and the gate then
    # passes and regresses
    summary.write_text(json.dumps({
        "benches": {
            "sampler": {"samplers": {
                "parallel": {"tokens_per_s": 100},
                "kernel": {"tokens_per_s": 100}}},
            "batch": {"models_per_s": {"batched": 10}, "speedup": 5},
            "alias": {"tokens_per_s": {"alias": 1000}},
            "offload": {"offloaded_sweep_fraction": 0.7,
                        "no_phony_adopted": 1.0},
            "distributed": {"weak_scaling_efficiency": 1.0,
                            "sync_bytes_saving": 4.0},
            "obs": {"overhead_ok": 1.0},
            "delta_view": {"quantized_saving": 2.4},
        }}))
    assert perf_gate.main(["--summary", str(summary),
                           "--baseline", str(baseline), "--update"]) == 0
    assert perf_gate.main(
        ["--summary", str(summary), "--baseline", str(baseline),
         "--require",
         "sampler,batch,alias,offload,distributed,obs,delta_view"]) == 0
    summary.write_text(json.dumps({
        "benches": {
            "sampler": {"samplers": {
                "parallel": {"tokens_per_s": 50},  # -50%: regression
                "kernel": {"tokens_per_s": 100}}},
            "batch": {"models_per_s": {"batched": 10}, "speedup": 5},
            "alias": {"tokens_per_s": {"alias": 1000}},
            "offload": {"offloaded_sweep_fraction": 0.7,
                        "no_phony_adopted": 1.0},
            "distributed": {"weak_scaling_efficiency": 1.0,
                            "sync_bytes_saving": 4.0},
            "obs": {"overhead_ok": 1.0},
            "delta_view": {"quantized_saving": 2.4},
        }}))
    assert perf_gate.main(["--summary", str(summary),
                           "--baseline", str(baseline)]) == 1


def test_fit_batch_and_refine_many_stack_alias_backend(monkeypatch):
    """Regression: the service used to serialize any explicit non-batched
    backend; a backend with the stacked `run_many` surface (alias) must
    launch through the batch engine from fit_batch AND refine_many —
    observed directly by counting `run_batched` invocations, since every
    softer assertion also holds on the sequential fallback path."""
    launches = []
    real_run_batched = batch_engine.run_batched

    def counting_run_batched(sampler, *args, **kw):
        out, stats = real_run_batched(sampler, *args, **kw)
        launches.append((type(sampler).__name__, stats.num_launches))
        return out, stats

    monkeypatch.setattr(batch_engine, "run_batched", counting_run_batched)
    svc = VedaliaService(backend="auto", num_sweeps=4)
    handles = svc.fit_batch(_review_sets(3), num_topics=6, base_vocab=200,
                            backend="alias", seed=3)
    assert launches == [("AliasSampler", 1)]  # one stacked launch, not 3
    assert all(h.backend == "alias" for h in handles)
    # distinct per-handle chains (per-model key discipline held)
    assert not np.array_equal(np.asarray(handles[0].state.z[:50]),
                              np.asarray(handles[1].state.z[:50]))
    for h in handles:
        _assert_count_invariants(h.cfg, h.model.corpus, h.state)
    before = [h.sweeps_run for h in handles]
    svc.refine_many(handles, 2, backend="alias")
    assert launches == [("AliasSampler", 1)] * 2  # warm refit batched too
    assert [h.sweeps_run for h in handles] == [b + 2 for b in before]
    assert all(h.backend == "alias" for h in handles)
    assert all(svc.view(h).valid for h in handles)


def test_refine_many_batches_compatible_handles():
    svc = VedaliaService(backend="auto", num_sweeps=5)
    handles = svc.fit_batch(_review_sets(3), num_topics=6, base_vocab=200)
    before = [h.sweeps_run for h in handles]
    svc.refine_many(handles, 3)
    assert [h.sweeps_run for h in handles] == [b + 3 for b in before]
    assert all(h.backend == "batched" for h in handles)
    for h in handles:
        _assert_count_invariants(h.cfg, h.model.corpus, h.state)


# -- protocol ----------------------------------------------------------------


def test_protocol_fit_batch_and_refine_batch_roundtrip():
    client = VedaliaClient(backend="auto", num_sweeps=5)
    sets = _review_sets(3)
    fits = client.fit_batch(sets, num_topics=6, base_vocab=200)
    assert len(fits) == 3
    assert [f.backend for f in fits] == ["batched"] * 3
    assert [f.num_reviews for f in fits] == [len(rs) for rs in sets]
    refined = client.refine_batch([f.handle_id for f in fits], 2)
    assert [r.handle_id for r in refined] == [f.handle_id for f in fits]
    assert all(r.sweeps_run == f.sweeps_run + 2
               for r, f in zip(refined, fits))
    view = client.sync_view(fits[0].handle_id)
    assert view.valid and len(view.topics) >= 1


def test_protocol_refine_batch_unknown_handle_is_not_found():
    client = VedaliaClient(backend="jnp", num_sweeps=2)
    fit = client.fit(_review_sets(1)[0], num_topics=4, base_vocab=200)
    with pytest.raises(RemoteError) as e:
        client.refine_batch([fit.handle_id, 999], 1)
    assert e.value.code == "not_found"


# -- TopicEngine wave batching -----------------------------------------------


def test_topic_engine_fit_many_serves_batched_waves():
    eng = TopicEngine(backend="auto", num_sweeps=5, max_batch=4)
    sets = _review_sets(4)
    reqs = [FitRequest(uid=i, reviews=rs, num_topics=6, base_vocab=200)
            for i, rs in enumerate(sets)]
    results = eng.fit_many(reqs)
    assert [r.uid for r in results] == [0, 1, 2, 3]
    assert all(r.fit.backend == "batched" for r in results)
    assert all(np.isfinite(r.perplexity) for r in results)
    assert all(r.view.valid for r in results)
    # explicit per-model backend keeps the sequential path
    eng2 = TopicEngine(backend="jnp", num_sweeps=3, max_batch=4)
    res2 = eng2.fit_many([
        FitRequest(uid=9, reviews=sets[0], num_topics=4, base_vocab=200)])
    assert res2[0].fit.backend == "jnp"


# -- streaming refit coalescing ----------------------------------------------


def test_scheduler_coalesces_same_window_refits():
    client = VedaliaClient(backend="jnp", num_sweeps=4, update_sweeps=1)
    router = StreamRouter([0], capacity=256)
    sched = IncrementalScheduler(
        {0: client}, router, microbatch=3, min_fit_reviews=4,
        staleness_budget=100.0, refit_sweeps=2, refit_policy="always",
        heldout_every=1000,
        fit_kwargs=dict(num_topics=4, base_vocab=200, num_sweeps=3))

    sets = _review_sets(2, n=8)
    seq = 0
    # bootstrap both products
    for pid in (0, 1):
        for r in sets[pid][:4]:
            assert router.offer(ReviewEvent(seq=seq, t=0.1, product_id=pid,
                                            review=r))
            seq += 1
    sched.step(1.0)
    assert sched.stats.fits == 2 and sched.stats.refits == 0

    # one micro-batch per product inside the SAME scheduling window
    for pid in (0, 1):
        for r in sets[pid][4:7]:
            assert router.offer(ReviewEvent(seq=seq, t=1.1, product_id=pid,
                                            review=r))
            seq += 1
    sched.step(2.0)
    st = sched.stats
    assert st.updates == 2
    assert st.refits == 2  # both products refit (always policy)...
    assert st.refit_launches == 1  # ...in ONE coalesced refine_batch
    assert st.coalesced_refits == 1
    for status in sched.products.values():
        assert status.signatures  # re-anchored after the batched refit
        v = client.sync_view(status.handle_id)
        assert v.valid


def test_scheduler_falls_back_without_batched_backend():
    client = VedaliaClient(backend="jnp", num_sweeps=4, update_sweeps=1)
    router = StreamRouter([0], capacity=256)
    sched = IncrementalScheduler(
        {0: client}, router, microbatch=3, min_fit_reviews=4,
        staleness_budget=100.0, refit_sweeps=2, refit_policy="always",
        heldout_every=1000,
        fit_kwargs=dict(num_topics=4, base_vocab=200, num_sweeps=3))
    # a shard whose hello predates the batched backend
    sched._backends[0] = ["jnp", "alias", "sparse"]

    sets = _review_sets(2, n=8)
    seq = 0
    for pid in (0, 1):
        for r in sets[pid][:4]:
            assert router.offer(ReviewEvent(seq=seq, t=0.1, product_id=pid,
                                            review=r))
            seq += 1
    sched.step(1.0)
    for pid in (0, 1):
        for r in sets[pid][4:7]:
            assert router.offer(ReviewEvent(seq=seq, t=1.1, product_id=pid,
                                            review=r))
            seq += 1
    sched.step(2.0)
    st = sched.stats
    assert st.refits == 2 and st.refit_launches == 2
    assert st.coalesced_refits == 0
