"""Dry-run analysis machinery: HLO collective parsing + roofline math.

The dry-run itself needs 512 forced host devices (its own process); here we
test the pure pieces it is built from.
"""


from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def _collective_bytes(hlo):
    # import inside: repro.launch.dryrun sets XLA_FLAGS at import time; the
    # parsing helpers live on the module but only touch strings.
    from repro.launch.dryrun import collective_bytes

    return collective_bytes(hlo)


FAKE_HLO = """
  %ag = f32[128,256]{1,0} all-gather(%x), replica_groups=...
  %ar = bf16[64,64]{1,0} all-reduce(%y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(%z), dimensions={0}
  %aa = s32[16,8]{1,0} all-to-all(%w), dimensions={0}
  %cp = f32[4]{0} collective-permute(%v), source_target_pairs=...
  %not_a_collective = f32[9999]{0} add(%a, %b)
"""


def test_collective_parsing_counts_and_bytes():
    out = _collective_bytes(FAKE_HLO)
    assert out["bytes"]["all-gather"] == 128 * 256 * 4
    assert out["bytes"]["all-reduce"] == 64 * 64 * 2  # bf16
    assert out["bytes"]["reduce-scatter"] == 32 * 4
    assert out["bytes"]["all-to-all"] == 16 * 8 * 4
    assert out["bytes"]["collective-permute"] == 4 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total_bytes"] == sum(out["bytes"].values())
    # the non-collective op contributes nothing
    assert out["total_bytes"] < 9999 * 4 + 200000


def test_hardware_constants_are_v5e():
    assert PEAK_FLOPS_BF16 == 197e12
    assert HBM_BW == 819e9
    assert ICI_BW == 50e9


def test_model_flops_moe_active():
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline import active_param_count, param_count
    from repro import configs

    dense = configs.get("qwen2-7b")
    assert active_param_count(dense) == param_count(dense)
    moe = configs.get("arctic-480b")
    # top-2 of 128 experts: active far below total
    assert active_param_count(moe) < 0.2 * param_count(moe)
    ll4 = configs.get("llama4-maverick-400b-a17b")
    # ~17B active of ~395B total
    assert 10e9 < active_param_count(ll4) < 30e9
    assert 350e9 < param_count(ll4) < 450e9
