"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family runs one train step + one serving step on CPU, asserting
output shapes and no NaNs; prefill->decode agrees with the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.layers import logits_last
from repro.train.optim import OptConfig, make_optimizer
from repro.train.step import make_train_step

ALL_ARCHS = configs.ASSIGNED + ["gemma2-9b-sw"]


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = configs.get(name).reduced()
            params = M.init_model(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_full_config_matches_assignment(name):
    """The registered full config carries the exact assigned dimensions."""
    cfg = configs.get(name)
    assigned = {
        "rwkv6-1.6b": (24, 2048, 7168, 65536),
        "whisper-base": (6, 512, 2048, 51865),
        "arctic-480b": (35, 7168, 4864, 32000),
        "llama-3.2-vision-90b": (100, 8192, 28672, 128256),
        "qwen2-7b": (28, 3584, 18944, 152064),
        "llama4-maverick-400b-a17b": (48, 5120, 8192, 202048),
        "gemma-7b": (28, 3072, 24576, 256000),
        "zamba2-2.7b": (54, 2560, 10240, 32000),
        "phi3-medium-14b": (40, 5120, 17920, 100352),
        "gemma2-9b": (42, 3584, 14336, 256000),
        "gemma2-9b-sw": (42, 3584, 14336, 256000),
    }[name]
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == assigned
    assert cfg.citation


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step(arch_state, name):
    cfg, params = arch_state(name)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    opt = make_optimizer(OptConfig(name=cfg.optimizer, warmup_steps=1))
    step = make_train_step(cfg, opt)
    batch = {k: jnp.asarray(v)
             for k, v in M.real_batch(cfg, "train", 2, 64,
                                      jax.random.PRNGKey(1)).items()}
    opt_state = opt.init(params)
    new_params, new_opt, metrics = jax.jit(step)(
        params, opt_state, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_consistency(arch_state, name):
    cfg, params = arch_state(name)
    b, s = 2, 64
    key = jax.random.PRNGKey(2)
    full = M.real_batch(cfg, "prefill", b, s + 1, key)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :s]
    cache, logits_p = M.prefill(params, cfg, pre, cache_len=128)
    assert np.all(np.isfinite(np.asarray(logits_p, np.float32)))
    cache2, dec_logits = M.decode_step(
        params, cfg, cache, full["tokens"][:, s], jnp.int32(s))
    assert dec_logits.shape == (b, cfg.vocab_size)

    h, _, _ = M.forward_hidden(params, cfg, full, train=False)
    ref = logits_last(h[:, -1], M.unembed_table(params, cfg), cfg.final_softcap)
    err = float(jnp.max(jnp.abs(dec_logits - ref)))
    rel = err / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.02, (name, rel)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_multi_step_decode_no_nans(arch_state, name):
    cfg, params = arch_state(name)
    b, s = 2, 16
    batch = M.real_batch(cfg, "prefill", b, s, jax.random.PRNGKey(3))
    cache, logits = M.prefill(params, cfg, batch, cache_len=64)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        cache, logits = M.decode_step(params, cfg, cache, tok, jnp.int32(s + i))
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), (name, i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark."""
    import math

    expected = {  # (low, high) bounds in billions
        "qwen2-7b": (6, 9), "gemma-7b": (7, 10), "phi3-medium-14b": (12, 16),
        "gemma2-9b": (8, 11), "rwkv6-1.6b": (1.2, 2.2),
        "zamba2-2.7b": (2, 4), "whisper-base": (0.04, 0.12),
        "arctic-480b": (420, 520), "llama4-maverick-400b-a17b": (350, 450),
        "llama-3.2-vision-90b": (75, 105),
    }
    for name, (lo, hi) in expected.items():
        cfg = configs.get(name)
        n = 0
        for leaf in jax.tree.leaves(M.build_schema(cfg)):
            n += math.prod(leaf.shape)
        nb = n / 1e9
        assert lo <= nb <= hi, (name, nb)
