"""Collapsed-Gibbs samplers: blocked-parallel TPU path vs sequential refs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alias, gibbs, perplexity
from repro.core.sparse import DenseGibbsSampler, SparseLDASampler
from repro.core.types import Corpus, LDAConfig, build_counts, init_state


def _planted_corpus(n_docs=60, vocab=120, k=6, seed=0, mean_tokens=40):
    """Corpus with planted topics so convergence is measurable."""
    rng = np.random.default_rng(seed)
    phi = np.full((k, vocab), 0.02 / vocab)
    block = vocab // k
    for t in range(k):
        phi[t, t * block : (t + 1) * block] += 0.98 / block
    phi /= phi.sum(1, keepdims=True)
    docs, words = [], []
    for d in range(n_docs):
        theta = rng.dirichlet(np.full(k, 0.2))
        n = rng.poisson(mean_tokens) + 5
        zs = rng.choice(k, n, p=theta)
        for z in zs:
            docs.append(d)
            words.append(rng.choice(vocab, p=phi[z]))
    corpus = Corpus(
        docs=jnp.asarray(docs, jnp.int32),
        words=jnp.asarray(words, jnp.int32),
        weights=jnp.ones(len(docs), jnp.float32),
    )
    cfg = LDAConfig(num_topics=k, vocab_size=vocab, num_docs=n_docs)
    return cfg, corpus


def test_counts_consistency_after_sweep():
    cfg, corpus = _planted_corpus()
    state = gibbs.run(cfg, corpus, jax.random.PRNGKey(0), num_sweeps=3)
    rebuilt = build_counts(cfg, corpus, state.z)
    np.testing.assert_allclose(state.n_dt, rebuilt.n_dt, atol=1e-4)
    np.testing.assert_allclose(state.n_wt, rebuilt.n_wt, atol=1e-4)
    np.testing.assert_allclose(state.n_t, rebuilt.n_t, atol=1e-3)
    # totals conserved == total corpus weight
    assert np.isclose(float(state.n_t.sum()), float(corpus.weights.sum()), rtol=1e-5)


def test_parallel_gibbs_converges():
    cfg, corpus = _planted_corpus()
    st0 = init_state(cfg, corpus, jax.random.PRNGKey(1))
    p0 = perplexity.perplexity(cfg, st0, corpus)
    st = gibbs.run(cfg, corpus, jax.random.PRNGKey(2), num_sweeps=30)
    p1 = perplexity.perplexity(cfg, st, corpus)
    assert p1 < 0.6 * p0, (p0, p1)
    # should approach the planted structure: well below vocab-uniform
    assert p1 < cfg.vocab_size * 0.5


def test_parallel_matches_sequential_quality():
    """Blocked-parallel Gibbs reaches the same perplexity band as the
    faithful sequential SparseLDA sampler (the AD-LDA equivalence)."""
    cfg, corpus = _planted_corpus()
    st = gibbs.run(cfg, corpus, jax.random.PRNGKey(3), num_sweeps=40)
    p_par = perplexity.perplexity(cfg, st, corpus)

    seq = SparseLDASampler(
        cfg,
        np.asarray(corpus.docs),
        np.asarray(corpus.words),
        np.asarray(init_state(cfg, corpus, jax.random.PRNGKey(4)).z),
        seed=5,
    )
    seq.run(40)
    st_seq = build_counts(cfg, corpus, jnp.asarray(seq.z, jnp.int32))
    p_seq = perplexity.perplexity(cfg, st_seq, corpus)
    assert abs(np.log(p_par) - np.log(p_seq)) < 0.35, (p_par, p_seq)


def test_sparse_equals_dense_sequential():
    """SparseLDA's bucket decomposition is exact: same rng, same trajectory
    as the dense O(k) sampler for the first sweep? (They consume randomness
    differently, so compare converged quality instead.)"""
    cfg, corpus = _planted_corpus(n_docs=30, mean_tokens=25)
    z0 = np.asarray(init_state(cfg, corpus, jax.random.PRNGKey(0)).z)
    a = SparseLDASampler(cfg, np.asarray(corpus.docs), np.asarray(corpus.words), z0, seed=7)
    b = DenseGibbsSampler(cfg, np.asarray(corpus.docs), np.asarray(corpus.words), z0, seed=7)
    a.run(25)
    b.run(25)
    pa = perplexity.perplexity(cfg, build_counts(cfg, corpus, jnp.asarray(a.z, jnp.int32)), corpus)
    pb = perplexity.perplexity(cfg, build_counts(cfg, corpus, jnp.asarray(b.z, jnp.int32)), corpus)
    assert abs(np.log(pa) - np.log(pb)) < 0.3, (pa, pb)


def test_fixed_point_path_tracks_float_path():
    cfg, corpus = _planted_corpus()
    cfg_fx = LDAConfig(
        num_topics=cfg.num_topics, vocab_size=cfg.vocab_size,
        num_docs=cfg.num_docs, w_bits=8,
    )
    st_f = gibbs.run(cfg, corpus, jax.random.PRNGKey(6), num_sweeps=25)
    st_x = gibbs.run(cfg_fx, corpus, jax.random.PRNGKey(6), num_sweeps=25)
    pf = perplexity.perplexity(cfg, st_f, corpus)
    px = perplexity.perplexity(cfg_fx, st_x, corpus)
    assert abs(np.log(pf) - np.log(px)) < 0.2, (pf, px)


def test_alias_mh_sweep_converges():
    cfg, corpus = _planted_corpus()
    st = init_state(cfg, corpus, jax.random.PRNGKey(8))
    p0 = perplexity.perplexity(cfg, st, corpus)
    for i in range(30):
        st = alias.mh_sweep(cfg, st, corpus, jax.random.PRNGKey(10 + i), 4)
    p1 = perplexity.perplexity(cfg, st, corpus)
    assert p1 < 0.7 * p0, (p0, p1)


def _alias_reconstruction(thresh, al):
    """p[t] = (thresh[t] + Σ_{j: alias[j]==t} (1-thresh[j])) / k."""
    thresh, al = np.asarray(thresh), np.asarray(al)
    recon = thresh.copy()
    for j in range(len(thresh)):
        recon[al[j]] += 1.0 - thresh[j]
    return recon / len(thresh)


def test_alias_table_is_exact_distribution():
    """Alias table encodes the input distribution exactly."""
    rng = np.random.default_rng(0)
    for k in (2, 3, 8, 33, 64):
        p = rng.dirichlet(np.full(k, 0.4))
        thresh, al = alias.build_alias_table(jnp.asarray(p, jnp.float32))
        np.testing.assert_allclose(
            _alias_reconstruction(thresh, al), p, atol=2e-5)


def test_alias_table_exact_on_degenerate_rows():
    """Property sweep over the shapes that break pairing builders: the
    K-long drained-donor chain (one near-empty bucket), one-hot rows,
    zero-probability entries, exactly-uniform rows, and large K. Every
    threshold must stay in [0, 1] and the reconstruction must be exact."""
    rng = np.random.default_rng(1)
    cases = [
        np.r_[1e-7, np.full(63, (1 - 1e-7) / 63)],  # drain chain
        np.eye(16)[3],  # one-hot: zero-probability topics must never win
        np.r_[np.zeros(12), rng.dirichlet(np.full(4, 0.3))],
        np.full(32, 1 / 32),  # exactly uniform (all-heavy, zero excess)
        np.array([0.999, 0.001]),
        rng.dirichlet(np.full(256, 0.05)),  # large sparse K
    ]
    for p in cases:
        thresh, al = alias.build_alias_table(jnp.asarray(p, jnp.float32))
        t = np.asarray(thresh)
        assert ((t >= 0.0) & (t <= 1.0)).all(), p
        np.testing.assert_allclose(
            _alias_reconstruction(thresh, al), p / p.sum(), atol=2e-5)
        # zero-probability topics are unreachable: a zero bucket keeps no
        # mass and no bucket above threshold aliases into it
        zero = np.flatnonzero(p == 0.0)
        if zero.size:
            np.testing.assert_allclose(t[zero], 0.0, atol=1e-7)


def test_alias_table_zero_row_uniform_fallback():
    """An all-zero row (word never observed) falls back to an explicit
    uniform distribution, not an epsilon-normalized artifact."""
    thresh, al = alias.build_alias_table(jnp.zeros(16))
    np.testing.assert_allclose(np.asarray(thresh), 1.0)
    np.testing.assert_allclose(
        _alias_reconstruction(thresh, al), np.full(16, 1 / 16), atol=1e-7)


def test_alias_tables_batched_matches_per_row():
    """The whole-(V, K) vectorized builder == the single-row builder on
    every row, including a zero row mixed into the batch."""
    rng = np.random.default_rng(2)
    probs = rng.dirichlet(np.full(24, 0.2), size=40).astype(np.float32)
    probs[7] = 0.0
    thresh, al = alias.build_alias_tables(jnp.asarray(probs))
    assert thresh.shape == al.shape == (40, 24)
    for i in (0, 7, 13, 39):
        t_i, a_i = alias.build_alias_table(jnp.asarray(probs[i]))
        np.testing.assert_array_equal(np.asarray(thresh[i]), np.asarray(t_i))
        np.testing.assert_array_equal(np.asarray(al[i]), np.asarray(a_i))


def test_sweep_checkify_clean():
    """Sanitized leg (REPRO_SANITIZE=1): a full sweep is clean under
    checkify's float + index checks — no NaNs, no div-by-zero, and every
    count-table gather/scatter in bounds."""
    import os

    import pytest

    if os.environ.get("REPRO_SANITIZE") != "1":
        pytest.skip("sanitized leg only (set REPRO_SANITIZE=1)")
    from jax.experimental import checkify

    cfg, corpus = _planted_corpus(n_docs=20, vocab=60, k=4, mean_tokens=20)
    state = gibbs.run(cfg, corpus, jax.random.PRNGKey(0), num_sweeps=1)

    checked = checkify.checkify(
        lambda st, key: gibbs.sweep(cfg, st, corpus, key, block=256),
        errors=checkify.float_checks | checkify.index_checks,
    )
    err, new_state = checked(state, jax.random.PRNGKey(1))
    err.throw()
    assert new_state.z.shape == state.z.shape
