"""Collapsed-Gibbs samplers: blocked-parallel TPU path vs sequential refs."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alias, gibbs, perplexity
from repro.core.sparse import DenseGibbsSampler, SparseLDASampler
from repro.core.types import Corpus, LDAConfig, build_counts, init_state


def _planted_corpus(n_docs=60, vocab=120, k=6, seed=0, mean_tokens=40):
    """Corpus with planted topics so convergence is measurable."""
    rng = np.random.default_rng(seed)
    phi = np.full((k, vocab), 0.02 / vocab)
    block = vocab // k
    for t in range(k):
        phi[t, t * block : (t + 1) * block] += 0.98 / block
    phi /= phi.sum(1, keepdims=True)
    docs, words = [], []
    for d in range(n_docs):
        theta = rng.dirichlet(np.full(k, 0.2))
        n = rng.poisson(mean_tokens) + 5
        zs = rng.choice(k, n, p=theta)
        for z in zs:
            docs.append(d)
            words.append(rng.choice(vocab, p=phi[z]))
    corpus = Corpus(
        docs=jnp.asarray(docs, jnp.int32),
        words=jnp.asarray(words, jnp.int32),
        weights=jnp.ones(len(docs), jnp.float32),
    )
    cfg = LDAConfig(num_topics=k, vocab_size=vocab, num_docs=n_docs)
    return cfg, corpus


def test_counts_consistency_after_sweep():
    cfg, corpus = _planted_corpus()
    state = gibbs.run(cfg, corpus, jax.random.PRNGKey(0), num_sweeps=3)
    rebuilt = build_counts(cfg, corpus, state.z)
    np.testing.assert_allclose(state.n_dt, rebuilt.n_dt, atol=1e-4)
    np.testing.assert_allclose(state.n_wt, rebuilt.n_wt, atol=1e-4)
    np.testing.assert_allclose(state.n_t, rebuilt.n_t, atol=1e-3)
    # totals conserved == total corpus weight
    assert np.isclose(float(state.n_t.sum()), float(corpus.weights.sum()), rtol=1e-5)


def test_parallel_gibbs_converges():
    cfg, corpus = _planted_corpus()
    st0 = init_state(cfg, corpus, jax.random.PRNGKey(1))
    p0 = perplexity.perplexity(cfg, st0, corpus)
    st = gibbs.run(cfg, corpus, jax.random.PRNGKey(2), num_sweeps=30)
    p1 = perplexity.perplexity(cfg, st, corpus)
    assert p1 < 0.6 * p0, (p0, p1)
    # should approach the planted structure: well below vocab-uniform
    assert p1 < cfg.vocab_size * 0.5


def test_parallel_matches_sequential_quality():
    """Blocked-parallel Gibbs reaches the same perplexity band as the
    faithful sequential SparseLDA sampler (the AD-LDA equivalence)."""
    cfg, corpus = _planted_corpus()
    st = gibbs.run(cfg, corpus, jax.random.PRNGKey(3), num_sweeps=40)
    p_par = perplexity.perplexity(cfg, st, corpus)

    seq = SparseLDASampler(
        cfg,
        np.asarray(corpus.docs),
        np.asarray(corpus.words),
        np.asarray(init_state(cfg, corpus, jax.random.PRNGKey(4)).z),
        seed=5,
    )
    seq.run(40)
    st_seq = build_counts(cfg, corpus, jnp.asarray(seq.z, jnp.int32))
    p_seq = perplexity.perplexity(cfg, st_seq, corpus)
    assert abs(np.log(p_par) - np.log(p_seq)) < 0.35, (p_par, p_seq)


def test_sparse_equals_dense_sequential():
    """SparseLDA's bucket decomposition is exact: same rng, same trajectory
    as the dense O(k) sampler for the first sweep? (They consume randomness
    differently, so compare converged quality instead.)"""
    cfg, corpus = _planted_corpus(n_docs=30, mean_tokens=25)
    z0 = np.asarray(init_state(cfg, corpus, jax.random.PRNGKey(0)).z)
    a = SparseLDASampler(cfg, np.asarray(corpus.docs), np.asarray(corpus.words), z0, seed=7)
    b = DenseGibbsSampler(cfg, np.asarray(corpus.docs), np.asarray(corpus.words), z0, seed=7)
    a.run(25)
    b.run(25)
    pa = perplexity.perplexity(cfg, build_counts(cfg, corpus, jnp.asarray(a.z, jnp.int32)), corpus)
    pb = perplexity.perplexity(cfg, build_counts(cfg, corpus, jnp.asarray(b.z, jnp.int32)), corpus)
    assert abs(np.log(pa) - np.log(pb)) < 0.3, (pa, pb)


def test_fixed_point_path_tracks_float_path():
    cfg, corpus = _planted_corpus()
    cfg_fx = LDAConfig(
        num_topics=cfg.num_topics, vocab_size=cfg.vocab_size,
        num_docs=cfg.num_docs, w_bits=8,
    )
    st_f = gibbs.run(cfg, corpus, jax.random.PRNGKey(6), num_sweeps=25)
    st_x = gibbs.run(cfg_fx, corpus, jax.random.PRNGKey(6), num_sweeps=25)
    pf = perplexity.perplexity(cfg, st_f, corpus)
    px = perplexity.perplexity(cfg_fx, st_x, corpus)
    assert abs(np.log(pf) - np.log(px)) < 0.2, (pf, px)


def test_alias_mh_sweep_converges():
    cfg, corpus = _planted_corpus()
    st = init_state(cfg, corpus, jax.random.PRNGKey(8))
    p0 = perplexity.perplexity(cfg, st, corpus)
    for i in range(30):
        st = alias.mh_sweep(cfg, st, corpus, jax.random.PRNGKey(10 + i), 4)
    p1 = perplexity.perplexity(cfg, st, corpus)
    assert p1 < 0.7 * p0, (p0, p1)


def test_alias_table_is_exact_distribution():
    """Alias table encodes the input distribution exactly:
    p[t] = (thresh[t] + Σ_{j: alias[j]==t} (1-thresh[j])) / k."""
    rng = np.random.default_rng(0)
    for k in (2, 3, 8, 33, 64):
        p = rng.dirichlet(np.full(k, 0.4))
        thresh, al = alias.build_alias_table(jnp.asarray(p, jnp.float32))
        thresh, al = np.asarray(thresh), np.asarray(al)
        recon = thresh.copy()
        for j in range(k):
            recon[al[j]] += 1.0 - thresh[j]
        np.testing.assert_allclose(recon / k, p, atol=2e-5)
