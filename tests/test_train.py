"""Training subsystem: optimizers, grad accumulation, checkpointing, loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.loop import train
from repro.train.optim import OptConfig, make_optimizer, schedule
from repro.train.step import make_train_step


def test_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100, 200)]
    assert abs(lrs[0] - 1e-4) < 1e-9  # (0+1)/10 of peak: first step is real
    assert abs(lrs[2] - 1e-3) < 1e-9  # peak at end of warmup
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-4) < 1e-9  # floor
    assert lrs[5] == lrs[4]


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizer_reduces_loss(opt_name):
    cfg = configs.get("qwen2-7b").reduced()
    import dataclasses

    cfg = dataclasses.replace(cfg, optimizer=opt_name)
    params, hist = train(cfg, num_steps=40, seq_len=64, global_batch=8,
                         opt_cfg=OptConfig(name=opt_name, lr=1e-3,
                                           warmup_steps=5, decay_steps=40),
                         log_every=39)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.05


def test_microbatch_equals_full_batch_grads():
    """Gradient accumulation (fp32 accum) matches the single-shot step."""
    import dataclasses

    cfg = configs.get("phi3-medium-14b").reduced()
    cfg1 = dataclasses.replace(cfg, microbatch=1)
    cfg4 = dataclasses.replace(cfg, microbatch=4, grad_accum_dtype="float32")
    params = M.init_model(cfg1, jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig(lr=1e-2, warmup_steps=0, decay_steps=10))
    batch = {k: jnp.asarray(v) for k, v in
             M.real_batch(cfg1, "train", 8, 32, jax.random.PRNGKey(1)).items()}
    s1 = make_train_step(cfg1, opt)
    s4 = make_train_step(cfg4, opt)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch, jnp.int32(0))
    p4, _, m4 = jax.jit(s4)(params, opt.init(params), batch, jnp.int32(0))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3
    # Adam's elementwise normalization amplifies accumulation-order rounding
    # where v ~ 0, so compare by fraction-of-elements rather than allclose.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        af = np.asarray(a, np.float32)
        bf = np.asarray(b, np.float32)
        bad = np.abs(af - bf) > (5e-3 + 5e-2 * np.abs(bf))
        assert bad.mean() < 0.01, bad.mean()


def test_adafactor_state_is_factored():
    cfg = configs.get("arctic-480b").reduced()
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig(name="adafactor"))
    st = opt.init(params)
    p_leaves = jax.tree.leaves(params)
    s_bytes = sum(np.prod(x.shape) * 4 for x in jax.tree.leaves(st))
    p_bytes = sum(np.prod(x.shape) * x.dtype.itemsize for x in p_leaves)
    assert s_bytes < 0.6 * p_bytes  # factored: far below AdamW's 4x


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.get("gemma2-9b").reduced()
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptConfig())
    opt_state = opt.init(params)
    path = os.path.join(tmp_path, "ckpt.npz")
    ckpt.save(path, params, opt_state, step=17)
    p2, o2, step = ckpt.restore(path, params, opt_state)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # structure mismatch is caught
    import dataclasses

    cfg_other = configs.get("qwen2-7b").reduced()
    other = M.init_model(cfg_other, jax.random.PRNGKey(1))
    with pytest.raises((KeyError, ValueError)):
        ckpt.restore(path, other)


def test_loss_drops_on_learnable_bigram_data():
    """End-to-end: a small dense model learns the planted bigram process
    (entropy log(4) ≈ 1.39 << random ≈ 6.2)."""
    cfg = configs.get("phi3-medium-14b").reduced()
    params, hist = train(cfg, num_steps=120, seq_len=64, global_batch=16,
                         opt_cfg=OptConfig(lr=3e-3, warmup_steps=10,
                                           decay_steps=120),
                         log_every=20)
    assert hist[-1]["loss"] < hist[0]["loss"] - 1.0, hist
