"""Versioned Vedalia protocol: envelopes, server dispatch, delta views.

Covers the PR-2 acceptance gates:
  * envelope/array/review codecs round-trip; version mismatches are
    rejected on both sides;
  * `VedaliaClient` drives fit -> update -> view -> top_reviews ->
    release end-to-end through the wire;
  * delta views: an unchanged model syncs as 0 topic payloads, drifted
    topics are re-sent, dropped topics are announced, unknown cursors
    resync with a full view;
  * the benchmark aggregator errors on unknown --only names and emits
    one summary.json per run.
"""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.api import (
    PROTOCOL_VERSION,
    ProtocolError,
    RemoteError,
    VedaliaClient,
    VedaliaServer,
    protocol,
)
from repro.data import reviews

REPO = Path(__file__).resolve().parent.parent


def _reviews(n=30, vocab=120, seed=0):
    corp = reviews.generate(reviews.SyntheticSpec(
        num_reviews=n, vocab_size=vocab, num_topics=4, mean_tokens=30,
        seed=seed))
    return corp.reviews


@pytest.fixture()
def client():
    return VedaliaClient(backend="jnp", num_sweeps=5, update_sweeps=1)


# -- envelopes ---------------------------------------------------------------


def test_request_envelope_roundtrip():
    raw = protocol.make_request("fit", {"num_topics": 6})
    kind, payload = protocol.parse_request(raw)
    assert kind == "fit" and payload == {"num_topics": 6}
    assert json.loads(raw)["protocol_version"] == PROTOCOL_VERSION


def test_unknown_kind_rejected_both_ways():
    with pytest.raises(ProtocolError, match="unknown request kind"):
        protocol.make_request("steal_model")
    raw = json.dumps({"protocol_version": PROTOCOL_VERSION,
                      "kind": "steal_model", "payload": {}})
    with pytest.raises(ProtocolError, match="unknown request kind"):
        protocol.parse_request(raw)


def test_version_mismatch_rejected():
    stale = json.dumps({"protocol_version": PROTOCOL_VERSION + 1,
                        "kind": "hello", "payload": {}})
    with pytest.raises(ProtocolError, match="version mismatch"):
        protocol.parse_request(stale)
    # The server answers (never raises) with a version_mismatch error code.
    server = VedaliaServer(backend="jnp")
    env = json.loads(server.handle_raw(stale))
    assert env["ok"] is False
    assert env["error"]["code"] == "version_mismatch"
    # And the client refuses a response stamped with a foreign version.
    env = json.loads(protocol.make_response("hello", {}))
    env["protocol_version"] = PROTOCOL_VERSION + 1
    with pytest.raises(ProtocolError, match="version mismatch"):
        protocol.parse_response(json.dumps(env))


def test_error_envelope_surfaces_as_remote_error():
    raw = protocol.make_error("view", "not_found", "no such handle")
    with pytest.raises(RemoteError, match="no such handle") as ei:
        protocol.parse_response(raw)
    assert ei.value.code == "not_found"
    assert ei.value.kind == "view"


def test_response_kind_must_match_request():
    raw = protocol.make_response("view", {})
    with pytest.raises(ProtocolError, match="does not match"):
        protocol.parse_response(raw, expect_kind="fit")


@pytest.mark.parametrize("arr", [
    np.arange(12, dtype=np.int32).reshape(3, 4),
    np.linspace(0, 1, 7, dtype=np.float32),
    np.array([], dtype=np.int64),
])
def test_array_codec_roundtrip(arr):
    out = protocol.decode_array(protocol.encode_array(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_review_codec_roundtrip():
    r = _reviews(n=3)[1]
    r2 = protocol.decode_review(protocol.encode_review(r))
    np.testing.assert_array_equal(r2.tokens, np.asarray(r.tokens, np.int32))
    assert (r2.rating, r2.user, r2.helpful, r2.unhelpful) == (
        r.rating, r.user, r.helpful, r.unhelpful)
    assert r2.writing_quality == pytest.approx(r.writing_quality)


# -- handshake + lifecycle over the wire -------------------------------------


def test_hello_reports_version_backends_capabilities(client):
    info = client.hello()
    assert info.protocol_version == PROTOCOL_VERSION
    assert {"jnp", "pallas", "distributed", "alias", "sparse"} <= set(
        info.backends)
    assert info.capabilities["sparse"]["device_kind"] == "phone"
    assert info.capabilities["alias"]["proposal_based"] is True
    assert info.capabilities["jnp"]["warm_start"] is True


def test_client_fit_update_view_top_reviews_roundtrip(client):
    fit = client.fit(_reviews(n=30, seed=0), num_topics=6, base_vocab=120,
                     w_bits=8, seed=0)
    assert fit.num_reviews == 30 and fit.num_topics == 6
    assert np.isfinite(fit.perplexity)
    assert fit.backend == "jnp"

    upd = client.update(fit.handle_id, _reviews(n=8, seed=1), seed=1)
    assert upd.kind == "incremental"
    assert upd.num_new_reviews == 8

    view = client.sync_view(fit.handle_id, top_n=5, max_topics=4)
    assert view.valid and view.view.validate()
    assert not view.delta and view.cursor is not None
    assert 1 <= len(view.topics) <= 4
    assert view.payload_bytes == len(view.payload) > 0

    top = client.top_reviews(fit.handle_id, view.topic_ids[0], n=3)
    assert len(top.review_ids) == 3
    assert all(0 <= d < 38 for d in top.review_ids)

    assert client.perplexity(fit.handle_id) == pytest.approx(upd.perplexity)

    client.release(fit.handle_id)
    with pytest.raises(RemoteError) as ei:
        client.view(fit.handle_id)
    assert ei.value.code == "not_found"


def test_fit_prepared_by_reference_and_seller_flow(client):
    """The marketplace path: prepare once, fit twice by corpus id, the
    winner's handle serves, the loser and the corpus are released."""
    prep = client.prepare(_reviews(n=30, seed=0), base_vocab=120,
                          num_topics=6)
    assert prep.num_reviews == 30 and prep.num_tokens > 0
    a = client.fit_prepared(prep.corpus_id, num_sweeps=6, seed=1)
    b = client.fit_prepared(prep.corpus_id, num_sweeps=2, seed=2)
    assert a.handle_id != b.handle_id
    winner, loser = (a, b) if a.perplexity <= b.perplexity else (b, a)
    client.release(loser.handle_id)
    client.release_corpus(prep.corpus_id)
    # The winner's handle outlives the released corpus.
    assert client.sync_view(winner.handle_id).valid
    with pytest.raises(RemoteError):
        client.perplexity(loser.handle_id)
    with pytest.raises(RemoteError) as ei:
        client.fit_prepared(prep.corpus_id)
    assert ei.value.code == "not_found"


def test_adopt_uploads_external_state(client):
    """A device's locally-computed model rides the wire as b64 tensors and
    becomes a served handle; malformed shapes are rejected."""
    import jax

    from repro.api import get_backend
    from repro.core.types import LDAState

    prep_res = client.prepare(_reviews(n=25, seed=0), base_vocab=120,
                              num_topics=4)
    prep = client.server.preps[prep_res.corpus_id]  # the device's copy
    st = get_backend("jnp").run(prep.cfg, prep.corpus,
                                jax.random.PRNGKey(0), 5)
    fit = client.adopt(prep_res.corpus_id, st, backend="jnp", sweeps_run=5)
    assert fit.sweeps_run == 5 and np.isfinite(fit.perplexity)
    assert client.sync_view(fit.handle_id).valid

    bad = LDAState(z=st.z[:-1], n_dt=st.n_dt, n_wt=st.n_wt, n_t=st.n_t)
    with pytest.raises(RemoteError) as ei:
        client.adopt(prep_res.corpus_id, bad)
    assert ei.value.code == "invalid_argument"


def test_close_session_and_reopen(client):
    fit = client.fit(_reviews(n=25, seed=0), num_topics=4, base_vocab=120)
    client.sync_view(fit.handle_id)
    sid = client.session_id
    client.close()
    assert sid not in client.server.sessions
    assert client.session_id is None and client.cursors == {}
    client.close()  # idempotent
    assert not client.sync_view(fit.handle_id).delta  # fresh full sync


def test_evicted_session_recovers_with_full_resync():
    """A server that forgot the session (restart/eviction) must degrade to
    a full resync, not poison every later view call."""
    client = VedaliaClient(backend="jnp", num_sweeps=4, max_sessions=1)
    fit = client.fit(_reviews(n=25, seed=0), num_topics=4, base_vocab=120)
    client.sync_view(fit.handle_id)
    old_sid = client.session_id
    VedaliaClient(server=client.server)._ensure_session()  # evicts old_sid
    assert old_sid not in client.server.sessions
    recovered = client.sync_view(fit.handle_id)
    assert recovered.resync and len(recovered.topics) >= 1
    assert client.session_id != old_sid
    assert not client.sync_view(fit.handle_id).resync  # back to deltas


def test_refine_over_the_wire(client):
    fit = client.fit(_reviews(n=25, seed=0), num_topics=4, base_vocab=120)
    refined = client.refine(fit.handle_id, num_sweeps=3, backend="pallas")
    assert refined.sweeps_run == fit.sweeps_run + 3
    assert refined.backend == "pallas"


def test_server_answers_garbage_without_raising():
    server = VedaliaServer(backend="jnp")
    env = json.loads(server.handle_raw("not json at all"))
    assert env["ok"] is False and env["error"]["code"] == "bad_request"
    env = json.loads(server.handle_raw(
        protocol.make_request("fit", {"reviews": []})))
    assert env["ok"] is False
    # Missing required field -> bad_request, not a not_found masquerade.
    env = json.loads(server.handle_raw(protocol.make_request("view", {})))
    assert env["error"]["code"] == "bad_request"
    # Unknown backend name -> invalid_argument, listing the registry.
    env = json.loads(server.handle_raw(protocol.make_request(
        "fit", {"reviews": protocol.encode_reviews(_reviews(n=2)),
                "backend": "cuda"})))
    assert env["error"]["code"] == "invalid_argument"
    assert "sparse" in env["error"]["message"]


# -- delta views (§4.2) ------------------------------------------------------


def test_delta_view_of_unchanged_model_is_empty(client):
    fit = client.fit(_reviews(n=30, seed=0), num_topics=6, base_vocab=120,
                     seed=0)
    full = client.sync_view(fit.handle_id, top_n=6)
    assert not full.delta and len(full.topics) >= 1
    delta = client.sync_view(fit.handle_id, top_n=6)
    assert delta.delta and not delta.resync
    assert len(delta.topics) == 0
    assert delta.removed_topic_ids == []
    assert delta.topic_ids == full.topic_ids
    assert delta.payload_bytes < full.payload_bytes


def test_delta_view_resends_after_update(client):
    fit = client.fit(_reviews(n=40, seed=0), num_topics=6, base_vocab=120,
                     seed=0)
    client.sync_view(fit.handle_id, top_n=6)  # establish the cursor
    client.update(fit.handle_id, _reviews(n=10, seed=3), seed=2)
    delta = client.sync_view(fit.handle_id, top_n=6)
    assert delta.delta
    assert len(delta.topics) >= 1  # something drifted
    # Transmitted topics carry full payloads a device can apply directly.
    for t in delta.topics:
        assert t.topic_id in delta.topic_ids
        assert len(t.top_words) == len(t.top_word_weights)


def test_delta_view_announces_removed_topics(client):
    """Pin the viewed topic set explicitly: {0,1,2} then {0,1} must announce
    topic 2 as removed."""
    fit = client.fit(_reviews(n=30, seed=0), num_topics=6, base_vocab=120)
    client.view(fit.handle_id, topics=[0, 1, 2])
    cur = client.cursors[fit.handle_id]
    delta = client.view(fit.handle_id, since=cur, topics=[0, 1])
    assert delta.removed_topic_ids == [2]
    assert delta.topic_ids == [0, 1]


def test_unknown_cursor_falls_back_to_full_resync(client):
    fit = client.fit(_reviews(n=30, seed=0), num_topics=6, base_vocab=120)
    full = client.sync_view(fit.handle_id, top_n=6)
    stale = client.view(fit.handle_id, since="c999", top_n=6)
    assert stale.resync and not stale.delta
    assert len(stale.topics) == len(full.topics)


def test_cursor_storage_is_bounded_per_handle():
    client = VedaliaClient(backend="jnp", num_sweeps=4,
                           max_cursors_per_session=2)
    fit = client.fit(_reviews(n=25, seed=0), num_topics=4, base_vocab=120)
    cursors = [client.view(fit.handle_id).cursor for _ in range(4)]
    session = client.server.sessions[client.session_id]
    assert len(session.cursors[fit.handle_id]) == 2
    # The oldest cursor was pruned: using it now forces a resync.
    assert client.view(fit.handle_id, since=cursors[0]).resync
    assert not client.view(fit.handle_id, since=cursors[-1]).resync


def test_cursors_are_bound_to_their_handle():
    """A cursor cut from one handle must not diff another handle's view —
    and one busy handle must not evict a quieter handle's cursors."""
    client = VedaliaClient(backend="jnp", num_sweeps=4,
                           max_cursors_per_session=2)
    a = client.fit(_reviews(n=25, seed=0), num_topics=4, base_vocab=120)
    b = client.fit(_reviews(n=25, seed=1), num_topics=4, base_vocab=120)
    cur_a = client.view(a.handle_id).cursor
    cur_b = client.view(b.handle_id).cursor
    # Cross-handle cursor: safe resync, never a bogus delta.
    crossed = client.view(b.handle_id, since=cur_a)
    assert crossed.resync and crossed.removed_topic_ids == []
    # Handle A churning through cursors does not evict B's.
    for _ in range(5):
        client.view(a.handle_id)
    assert not client.view(b.handle_id, since=cur_b).resync
    # Releasing a handle drops its cursors from every session.
    client.release(a.handle_id)
    session = client.server.sessions[client.session_id]
    assert a.handle_id not in session.cursors
    assert b.handle_id in session.cursors


def test_view_without_session_has_no_cursor():
    server = VedaliaServer(backend="jnp", num_sweeps=4)
    client = VedaliaClient(server=server)
    fit = client.fit(_reviews(n=25, seed=0), num_topics=4, base_vocab=120)
    raw = server.handle_raw(protocol.make_request(
        "view", {"handle_id": fit.handle_id}))
    payload = protocol.parse_response(raw, expect_kind="view")
    assert payload["cursor"] is None  # stateless clients still get views
    assert len(payload["topics"]) >= 1


# -- benchmark aggregator (satellite) ----------------------------------------


def test_bench_runner_rejects_unknown_only_names():
    import os

    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "nosuchbench"],
        capture_output=True, text=True, cwd=REPO, env=env,
    )
    assert proc.returncode == 2
    assert "unknown bench name" in proc.stderr
    assert "sampler" in proc.stderr  # the valid names are listed


def test_bench_runner_writes_aggregate_summary(tmp_path, monkeypatch):
    import importlib
    import sys as _sys
    import types

    _sys.path.insert(0, str(REPO))
    try:
        run_mod = importlib.import_module("benchmarks.run")
    finally:
        _sys.path.pop(0)

    dummy = types.ModuleType("tests._dummy_bench")
    dummy.run = lambda quick=False: {"quick": quick, "metric": 42}
    monkeypatch.setitem(_sys.modules, "tests._dummy_bench", dummy)
    monkeypatch.setattr(run_mod, "BENCHES", [
        ("dummy", "a stub", "tests._dummy_bench")])
    run_mod.main(["--only", "dummy", "--outdir", str(tmp_path)])

    summary = json.loads((tmp_path / "summary.json").read_text())
    assert summary["failures"] == []
    assert summary["benches"]["dummy"]["metric"] == 42
    assert summary["profile"] == "quick"
    assert json.loads((tmp_path / "dummy.json").read_text())["metric"] == 42
